//! §IV future-work 1 — **parallelization** of Algorithm 1.
//!
//! Two projection steps at pages `k` and `k'` commute *exactly* when the
//! supports of their columns are disjoint: `supp B(:,k) = {k} ∪ out(k)`.
//! If additionally neither update *reads* what the other *writes* (same
//! condition), a batch of such pages can be activated simultaneously and
//! the result equals any sequential ordering of the same activations.
//!
//! [`ParallelMatchingPursuit`] samples candidate pages uniformly,
//! greedily packs a conflict-free subset (first-come-first-kept, so the
//! marginal distribution of the first accepted page stays uniform), then
//! applies the batch. The projections touch pairwise-disjoint coordinate
//! sets, so the sequential application below is semantically identical to
//! a simultaneous distributed execution — verified against a reversed
//! ordering in the tests.
//!
//! The ablation bench measures effective speedup (activations per batch)
//! as a function of requested batch size and graph density — dense graphs
//! (like the paper's N=100, p=0.5 model) admit only tiny batches, sparse
//! web-like graphs admit large ones; this quantifies the paper's open
//! question.

use crate::graph::Graph;
use crate::linalg::sparse::BColumns;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Batched conflict-free MP.
#[derive(Debug, Clone)]
pub struct ParallelMatchingPursuit<'g> {
    graph: &'g Graph,
    cols: BColumns,
    x: Vec<f64>,
    r: Vec<f64>,
    batch: usize,
    /// Scratch marker per page: generation tag to avoid clearing.
    mark: Vec<u64>,
    generation: u64,
    /// Batch-size history (for the ablation's effective-parallelism plot).
    batch_sizes: Vec<usize>,
}

impl<'g> ParallelMatchingPursuit<'g> {
    pub fn new(graph: &'g Graph, alpha: f64, batch: usize) -> Self {
        assert!(batch >= 1);
        let n = graph.n();
        let y = 1.0 - alpha;
        ParallelMatchingPursuit {
            cols: BColumns::new(graph, alpha),
            graph,
            x: vec![0.0; n],
            r: vec![y; n],
            batch,
            mark: vec![0; n],
            generation: 0,
            batch_sizes: Vec::new(),
        }
    }

    /// Greedily pack a conflict-free subset from `batch` uniform
    /// candidates. Returns the accepted pages.
    pub fn pack_batch(&mut self, rng: &mut Rng) -> Vec<usize> {
        self.generation += 1;
        let gen = self.generation;
        let mut accepted = Vec::with_capacity(self.batch);
        'cand: for _ in 0..self.batch {
            let k = rng.below(self.graph.n());
            // Conflict iff closed neighbourhood intersects an accepted one.
            if self.mark[k] == gen {
                continue;
            }
            for &j in self.graph.out(k) {
                if self.mark[j as usize] == gen {
                    continue 'cand;
                }
            }
            // Accept: mark the closed neighbourhood.
            self.mark[k] = gen;
            for &j in self.graph.out(k) {
                self.mark[j as usize] = gen;
            }
            accepted.push(k);
        }
        accepted
    }

    /// Apply a set of *assumed conflict-free* activations.
    pub fn apply_batch(&mut self, pages: &[usize]) {
        for &k in pages {
            let num = self.cols.col_dot(self.graph, k, &self.r);
            let coef = num / self.cols.norm_sq(k);
            self.x[k] += coef;
            self.cols.sub_scaled_col(self.graph, k, coef, &mut self.r);
        }
    }

    /// Mean accepted batch size so far (effective parallelism).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn residual(&self) -> &[f64] {
        &self.r
    }
}

impl<'g> PageRankSolver for ParallelMatchingPursuit<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    /// One step = one packed batch (counts as `batch_size` activations).
    fn step(&mut self, rng: &mut Rng) -> StepStats {
        let pages = self.pack_batch(rng);
        let mut stats = StepStats::default();
        for &k in &pages {
            let d = self.graph.out_degree(k);
            stats.reads += d;
            stats.writes += d;
        }
        stats.activated = pages.len();
        self.batch_sizes.push(pages.len());
        self.apply_batch(&pages);
        stats
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "parallel MP (conflict-free batches)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mp::MatchingPursuit;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn packed_batches_are_conflict_free() {
        let g = generators::erdos_renyi(200, 0.02, 101);
        let mut pmp = ParallelMatchingPursuit::new(&g, 0.85, 16);
        let mut rng = Rng::seeded(102);
        for _ in 0..50 {
            let batch = pmp.pack_batch(&mut rng);
            // Closed neighbourhoods pairwise disjoint.
            let mut seen = std::collections::BTreeSet::new();
            for &k in &batch {
                let mut nb: Vec<usize> = g.out(k).iter().map(|&v| v as usize).collect();
                nb.push(k);
                nb.sort_unstable();
                nb.dedup(); // self-loops put k in out(k) too
                for v in nb {
                    assert!(seen.insert(v), "conflict at page {v} in batch {batch:?}");
                }
            }
            pmp.apply_batch(&batch);
        }
    }

    #[test]
    fn batch_equals_sequential_on_disjoint_supports() {
        let g = generators::erdos_renyi(100, 0.02, 103);
        let mut pmp = ParallelMatchingPursuit::new(&g, 0.85, 8);
        let mut rng = Rng::seeded(104);
        let batch = pmp.pack_batch(&mut rng);
        assert!(batch.len() > 1, "need a real batch for this test");
        // Sequential reference in a *reversed* order — commutativity.
        let mut seq = MatchingPursuit::new(&g, 0.85);
        for &k in batch.iter().rev() {
            seq.step_at(k);
        }
        pmp.apply_batch(&batch);
        assert!(vector::dist_inf(pmp.residual(), seq.residual()) < 1e-14);
        assert!(vector::dist_inf(&pmp.estimate(), &seq.estimate()) < 1e-14);
    }

    #[test]
    fn dense_graph_packs_tiny_batches() {
        // Paper's model (p=0.5 dense): conflict everywhere, batches ~1.
        let g = generators::er_threshold(100, 0.5, 105);
        let mut pmp = ParallelMatchingPursuit::new(&g, 0.85, 32);
        let mut rng = Rng::seeded(106);
        for _ in 0..100 {
            pmp.step(&mut rng);
        }
        assert!(pmp.mean_batch_size() < 3.0, "dense graphs cannot parallelize: {}", pmp.mean_batch_size());
    }

    #[test]
    fn sparse_graph_packs_large_batches() {
        let g = generators::erdos_renyi(500, 0.004, 107);
        let mut pmp = ParallelMatchingPursuit::new(&g, 0.85, 32);
        let mut rng = Rng::seeded(108);
        for _ in 0..100 {
            pmp.step(&mut rng);
        }
        assert!(pmp.mean_batch_size() > 10.0, "sparse graphs parallelize: {}", pmp.mean_batch_size());
    }

    #[test]
    fn converges_to_exact() {
        let g = generators::erdos_renyi(60, 0.08, 109);
        let x_star = exact_pagerank(&g, 0.85);
        let mut pmp = ParallelMatchingPursuit::new(&g, 0.85, 8);
        let mut rng = Rng::seeded(110);
        for _ in 0..40_000 {
            pmp.step(&mut rng);
        }
        assert!(vector::dist_inf(&pmp.estimate(), &x_star) < 1e-7);
    }

    #[test]
    fn batch_one_matches_plain_mp() {
        let g = generators::er_threshold(20, 0.5, 111);
        let mut pmp = ParallelMatchingPursuit::new(&g, 0.85, 1);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut r1 = Rng::seeded(7);
        let mut r2 = Rng::seeded(7);
        for _ in 0..500 {
            pmp.step(&mut r1);
            mp.step(&mut r2);
        }
        assert!(vector::dist_inf(&pmp.estimate(), &mp.estimate()) < 1e-14);
    }
}
