//! Ablation: the *original* Matching Pursuit with best-atom selection
//! (Mallat & Zhang \[2\]), which the paper randomizes away.
//!
//! At each step pick `k* = argmax_k |B(:,k)ᵀ r| / ‖B(:,k)‖` — the atom
//! most correlated with the residual — then project as in eqs. 7–8. This
//! converges at least as fast per iteration as the randomized rule but
//! requires a *global* search over all pages ("not amendable to a
//! distributed implementation", §II-B). The ablation bench quantifies the
//! iteration-count vs. communication trade.
//!
//! The scan is O(Σ N_k) = O(m) per step done naively; we maintain the
//! correlations incrementally: an activation at `k` changes `B(:,j)ᵀ r`
//! only for pages `j` whose columns overlap the support of `B(:,k)` —
//! we simply recompute the numerators of affected pages via in-adjacency
//! of the touched coordinates.

use crate::graph::Graph;
use crate::linalg::sparse::BColumns;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Greedy (best-atom) Matching Pursuit.
#[derive(Debug, Clone)]
pub struct GreedyMatchingPursuit<'g> {
    graph: &'g Graph,
    cols: BColumns,
    x: Vec<f64>,
    r: Vec<f64>,
    /// Cached numerators B(:,k)ᵀ r for all k.
    num: Vec<f64>,
    /// 1/‖B(:,k)‖ for the selection score.
    inv_norm: Vec<f64>,
}

impl<'g> GreedyMatchingPursuit<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        let n = graph.n();
        let cols = BColumns::new(graph, alpha);
        let y = 1.0 - alpha;
        let r = vec![y; n];
        let num: Vec<f64> = (0..n).map(|k| cols.col_dot(graph, k, &r)).collect();
        let inv_norm: Vec<f64> = (0..n).map(|k| 1.0 / cols.norm_sq(k).sqrt()).collect();
        GreedyMatchingPursuit {
            graph,
            cols,
            x: vec![0.0; n],
            r,
            num,
            inv_norm,
        }
    }

    /// Best-matching atom under the |B(:,k)ᵀr|/‖B(:,k)‖ score.
    pub fn best_atom(&self) -> usize {
        let mut best = 0usize;
        let mut best_score = f64::MIN;
        for k in 0..self.num.len() {
            let score = self.num[k].abs() * self.inv_norm[k];
            if score > best_score {
                best_score = score;
                best = k;
            }
        }
        best
    }

    /// Project on a chosen atom and refresh affected numerators.
    /// Returns (touched coordinates, pages rescanned).
    pub fn step_at(&mut self, k: usize) -> (usize, usize) {
        let coef = self.num[k] / self.cols.norm_sq(k);
        self.x[k] += coef;
        self.cols.sub_scaled_col(self.graph, k, coef, &mut self.r);
        // Coordinates whose residual changed: {k} ∪ out(k).
        // Numerator of page j depends on r over {j} ∪ out(j); page j is
        // affected iff its closed out-neighbourhood intersects the
        // touched set — i.e. j ∈ touched ∪ in(touched).
        let mut affected: Vec<u32> = Vec::new();
        let push = |v: u32, acc: &mut Vec<u32>| {
            if !acc.contains(&v) {
                acc.push(v);
            }
        };
        let touched: Vec<u32> = {
            let mut t = self.graph.out(k).to_vec();
            push(k as u32, &mut t);
            t
        };
        for &c in &touched {
            push(c, &mut affected);
            for &j in self.graph.inc(c as usize) {
                push(j, &mut affected);
            }
        }
        for &j in &affected {
            self.num[j as usize] = self.cols.col_dot(self.graph, j as usize, &self.r);
        }
        (touched.len(), affected.len())
    }

    pub fn residual_norm_sq(&self) -> f64 {
        crate::linalg::vector::norm2_sq(&self.r)
    }
}

impl<'g> PageRankSolver for GreedyMatchingPursuit<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, _rng: &mut Rng) -> StepStats {
        let k = self.best_atom();
        let deg = self.graph.out_degree(k);
        let (_, rescanned) = self.step_at(k);
        StepStats {
            // The argmax itself reads every page's score: global cost.
            reads: self.graph.n() + rescanned,
            writes: deg,
            activated: 1,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "greedy MP (best atom, centralized)"
    }

    fn requires_in_links(&self) -> bool {
        true // incremental correlation maintenance scans in-neighbours
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mp::MatchingPursuit;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn cached_numerators_stay_exact() {
        let g = generators::er_threshold(25, 0.5, 91);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(92);
        for _ in 0..50 {
            gmp.step(&mut rng);
            for k in 0..25 {
                let want = gmp.cols.col_dot(gmp.graph, k, &gmp.r);
                assert!(
                    (gmp.num[k] - want).abs() < 1e-10,
                    "stale numerator at {k}"
                );
            }
        }
    }

    #[test]
    fn converges_faster_per_iteration_than_random() {
        let g = generators::er_threshold(30, 0.5, 93);
        let steps = 1500;
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng1 = Rng::seeded(94);
        let mut rng2 = Rng::seeded(94);
        for _ in 0..steps {
            gmp.step(&mut rng1);
            mp.step(&mut rng2);
        }
        assert!(
            gmp.residual_norm_sq() <= mp.residual_norm_sq() * 1.01,
            "greedy {} vs random {}",
            gmp.residual_norm_sq(),
            mp.residual_norm_sq()
        );
    }

    #[test]
    fn converges_to_exact() {
        let g = generators::er_threshold(20, 0.5, 95);
        let x_star = exact_pagerank(&g, 0.85);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(96);
        for _ in 0..20_000 {
            gmp.step(&mut rng);
        }
        assert!(vector::dist_inf(&gmp.estimate(), &x_star) < 1e-8);
    }

    #[test]
    fn selection_is_argmax() {
        let g = generators::er_threshold(15, 0.5, 97);
        let gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let k = gmp.best_atom();
        let score = |j: usize| gmp.num[j].abs() * gmp.inv_norm[j];
        for j in 0..15 {
            assert!(score(k) >= score(j) - 1e-15);
        }
    }

    #[test]
    fn global_read_cost_reported() {
        let g = generators::er_threshold(12, 0.5, 98);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(99);
        let st = gmp.step(&mut rng);
        assert!(st.reads >= 12, "argmax must cost at least N reads");
    }
}
