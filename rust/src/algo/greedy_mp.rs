//! Ablation: the *original* Matching Pursuit with best-atom selection
//! (Mallat & Zhang \[2\]), which the paper randomizes away.
//!
//! At each step pick `k* = argmax_k |B(:,k)ᵀ r| / ‖B(:,k)‖` — the atom
//! most correlated with the residual — then project as in eqs. 7–8. This
//! converges at least as fast per iteration as the randomized rule but
//! requires a *global* search over all pages ("not amendable to a
//! distributed implementation", §II-B). The ablation bench quantifies the
//! iteration-count vs. communication trade.
//!
//! The scan is O(Σ N_k) = O(m) per step done naively; we maintain the
//! correlations incrementally: an activation at `k` changes `B(:,j)ᵀ r`
//! only for pages `j` whose columns overlap the support of `B(:,k)` —
//! we recompute the numerators of affected pages via in-adjacency of the
//! touched coordinates. The argmax itself is a [`MaxScoreTree`] point
//! query: the affected pages' scores are point-updated in O(log N) each,
//! so one step costs O(log N · |{k} ∪ in(out(k))|) instead of the O(N)
//! full-score scan the seed implementation paid — which is what lets the
//! ablation run at 10⁵⁺ pages (see `benches/ablation.rs`, ABL-GREEDY-SCALE).

use crate::graph::Graph;
use crate::linalg::select::MaxScoreTree;
use crate::linalg::sparse::BColumns;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Greedy (best-atom) Matching Pursuit.
#[derive(Debug, Clone)]
pub struct GreedyMatchingPursuit<'g> {
    graph: &'g Graph,
    cols: BColumns,
    x: Vec<f64>,
    r: Vec<f64>,
    /// Cached numerators B(:,k)ᵀ r for all k.
    num: Vec<f64>,
    /// 1/‖B(:,k)‖ for the selection score.
    inv_norm: Vec<f64>,
    /// Selection engine over the scores `|num[k]| · inv_norm[k]`.
    tree: MaxScoreTree,
    /// Generation-stamped dedup marks for the affected-set walk (O(1)
    /// membership instead of a Vec::contains scan).
    mark: Vec<u64>,
    gen: u64,
    /// Recycled affected-set buffer (no per-step allocation).
    scratch: Vec<u32>,
}

impl<'g> GreedyMatchingPursuit<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        let n = graph.n();
        let cols = BColumns::new(graph, alpha);
        let y = 1.0 - alpha;
        let r = vec![y; n];
        let num: Vec<f64> = (0..n).map(|k| cols.col_dot(graph, k, &r)).collect();
        let inv_norm: Vec<f64> = (0..n).map(|k| 1.0 / cols.norm_sq(k).sqrt()).collect();
        let scores: Vec<f64> = (0..n).map(|k| num[k].abs() * inv_norm[k]).collect();
        GreedyMatchingPursuit {
            graph,
            cols,
            x: vec![0.0; n],
            r,
            num,
            inv_norm,
            tree: MaxScoreTree::new(&scores),
            mark: vec![0; n],
            gen: 0,
            scratch: Vec::new(),
        }
    }

    /// Best-matching atom under the |B(:,k)ᵀr|/‖B(:,k)‖ score — an
    /// O(log N) tree descent, not a scan (ties resolve to the lowest
    /// index, same as a first-wins linear scan).
    pub fn best_atom(&self) -> usize {
        self.tree.argmax()
    }

    /// Project on a chosen atom and refresh affected numerators and
    /// selection scores. Returns (touched coordinates, pages rescanned).
    pub fn step_at(&mut self, k: usize) -> (usize, usize) {
        let coef = self.num[k] / self.cols.norm_sq(k);
        self.x[k] += coef;
        self.cols.sub_scaled_col(self.graph, k, coef, &mut self.r);
        // Coordinates whose residual changed: {k} ∪ out(k).
        // Numerator of page j depends on r over {j} ∪ out(j); page j is
        // affected iff its closed out-neighbourhood intersects the
        // touched set — i.e. j ∈ touched ∪ in(touched).
        self.gen += 1;
        let gen = self.gen;
        let mut affected = std::mem::take(&mut self.scratch);
        affected.clear();
        if self.mark[k] != gen {
            self.mark[k] = gen;
            affected.push(k as u32);
        }
        for &c in self.graph.out(k) {
            let ci = c as usize;
            if self.mark[ci] != gen {
                self.mark[ci] = gen;
                affected.push(c);
            }
        }
        let touched = affected.len();
        for i in 0..touched {
            let c = affected[i] as usize;
            for &j in self.graph.inc(c) {
                let ji = j as usize;
                if self.mark[ji] != gen {
                    self.mark[ji] = gen;
                    affected.push(j);
                }
            }
        }
        for &j in &affected {
            let j = j as usize;
            self.num[j] = self.cols.col_dot(self.graph, j, &self.r);
            self.tree.update(j, self.num[j].abs() * self.inv_norm[j]);
        }
        let rescanned = affected.len();
        self.scratch = affected;
        (touched, rescanned)
    }

    pub fn residual_norm_sq(&self) -> f64 {
        crate::linalg::vector::norm2_sq(&self.r)
    }
}

impl<'g> PageRankSolver for GreedyMatchingPursuit<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, _rng: &mut Rng) -> StepStats {
        let k = self.best_atom();
        let deg = self.graph.out_degree(k);
        let (_, rescanned) = self.step_at(k);
        StepStats {
            // Selection is an O(log N) tree descent; the per-step read
            // cost is the affected-neighbourhood rescan (the seed
            // implementation paid N extra reads here for the full-score
            // argmax scan).
            reads: rescanned,
            writes: deg,
            activated: 1,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "greedy MP (best atom, centralized)"
    }

    fn requires_in_links(&self) -> bool {
        true // incremental correlation maintenance scans in-neighbours
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mp::MatchingPursuit;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn cached_numerators_stay_exact() {
        let g = generators::er_threshold(25, 0.5, 91);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(92);
        for _ in 0..50 {
            gmp.step(&mut rng);
            for k in 0..25 {
                let want = gmp.cols.col_dot(gmp.graph, k, &gmp.r);
                assert!(
                    (gmp.num[k] - want).abs() < 1e-10,
                    "stale numerator at {k}"
                );
            }
        }
    }

    #[test]
    fn tree_scores_stay_in_sync_with_numerators() {
        // The selection tree must track |num|·inv_norm exactly through
        // incremental updates — a stale score would silently change the
        // argmax away from the Mallat–Zhang rule.
        let g = generators::erdos_renyi(60, 0.1, 90);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(93);
        for _ in 0..200 {
            gmp.step(&mut rng);
        }
        for k in 0..60 {
            let want = gmp.num[k].abs() * gmp.inv_norm[k];
            assert_eq!(gmp.tree.score(k), want, "stale tree score at {k}");
        }
    }

    #[test]
    fn converges_faster_per_iteration_than_random() {
        let g = generators::er_threshold(30, 0.5, 93);
        let steps = 1500;
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng1 = Rng::seeded(94);
        let mut rng2 = Rng::seeded(94);
        for _ in 0..steps {
            gmp.step(&mut rng1);
            mp.step(&mut rng2);
        }
        assert!(
            gmp.residual_norm_sq() <= mp.residual_norm_sq() * 1.01,
            "greedy {} vs random {}",
            gmp.residual_norm_sq(),
            mp.residual_norm_sq()
        );
    }

    #[test]
    fn converges_to_exact() {
        let g = generators::er_threshold(20, 0.5, 95);
        let x_star = exact_pagerank(&g, 0.85);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(96);
        for _ in 0..20_000 {
            gmp.step(&mut rng);
        }
        assert!(vector::dist_inf(&gmp.estimate(), &x_star) < 1e-8);
    }

    #[test]
    fn selection_is_argmax() {
        let g = generators::er_threshold(15, 0.5, 97);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let score = |g: &GreedyMatchingPursuit, j: usize| g.num[j].abs() * g.inv_norm[j];
        let k = gmp.best_atom();
        for j in 0..15 {
            assert!(score(&gmp, k) >= score(&gmp, j) - 1e-15);
        }
        // And it stays the argmax after incremental updates.
        let mut rng = Rng::seeded(98);
        for _ in 0..100 {
            gmp.step(&mut rng);
            let k = gmp.best_atom();
            for j in 0..15 {
                assert!(score(&gmp, k) >= score(&gmp, j) - 1e-15, "stale argmax at {j}");
            }
        }
    }

    #[test]
    fn selection_cost_is_local_not_global() {
        // Regression for the O(N) per-step argmax scan: on a ring the
        // affected set of any activation is {k-1, k, k+1}, so the
        // reported per-step read cost must be ≤ 3 — far below N.
        let g = generators::ring(64);
        let mut gmp = GreedyMatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(99);
        for _ in 0..50 {
            let st = gmp.step(&mut rng);
            assert!(st.activated == 1);
            assert!(
                (1..=3).contains(&st.reads),
                "ring rescan must touch 1..=3 pages, got {}",
                st.reads
            );
        }
    }
}
