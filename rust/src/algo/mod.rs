//! PageRank algorithms: the paper's contribution, its baselines, and the
//! §IV future-work extensions.
//!
//! | module | algorithm | information used | expected rate |
//! |--------|-----------|------------------|---------------|
//! | [`mp`] | **Algorithm 1** — randomized Matching Pursuit | out-links only | exponential (Prop. 2) |
//! | [`size_estimation`] | **Algorithm 2** — Kaczmarz size estimator | out-links only | exponential (Appendix) |
//! | [`power_iteration`] | centralized Jacobi/power iteration | global | exponential (rate α), centralized |
//! | [`ishii_tempo`] | \[6\] randomized power iteration + Polyak averaging | in-links | sub-exponential O(1/t) |
//! | [`you_tempo_qiu`] | \[15\] randomized incremental (row Kaczmarz) | in-links | exponential |
//! | [`lei_chen`] | \[12\] stochastic approximation | in-links | sub-exponential |
//! | [`monte_carlo`] | \[9\] random-walk frequency estimator | out-links | 1/√R Monte-Carlo |
//! | [`greedy_mp`] | original (non-randomized) best-atom MP | global argmax | exponential, not distributed |
//! | [`parallel_mp`] | §IV-1 conflict-free parallel activation | out-links | exponential, batched |
//! | [`dense_engine`] | dense-matrix Jacobi (host twin of the PJRT backend) | global, O(N²) | exponential (rate α), centralized |
//! | [`dynamic`] | §IV-2 dynamic-network warm restart | out-links | local repair + resume |
//! | [`stopping`] | §IV-4 ranking certification | `‖r_t‖` + σ(B) | — |
//!
//! Non-uniform (residual-weighted) sampling — §IV-3 — lives in
//! [`crate::coordinator::sampler`] since sampling is a coordinator
//! concern; `mp::MatchingPursuit::step_at` lets any sampler drive the
//! same update rule.

pub mod common;
pub mod dense_engine;
pub mod dynamic;
pub mod greedy_mp;
pub mod ishii_tempo;
pub mod lei_chen;
pub mod monte_carlo;
pub mod mp;
pub mod parallel_mp;
pub mod power_iteration;
pub mod size_estimation;
pub mod stopping;
pub mod you_tempo_qiu;

pub use common::{PageRankSolver, StepStats, Trajectory};
