//! Baseline \[9\]: Das Sarma, Molla, Pandurangan & Upfal, *Fast
//! distributed PageRank computation* (ICDCN 2013) — Monte-Carlo random
//! walks.
//!
//! The estimator uses the Neumann-series identity behind Proposition 1:
//! `x* = (1-α) Σ_k α^k A^k 𝟙`, i.e. starting one α-terminated random walk
//! from every page, `E[visits to i] = x*_i / (1-α)`. With `R` rounds of
//! walks, `x̂_i = (1-α) · visits_i / R`.
//!
//! The paper under reproduction notes the drawback this module measures:
//! *"the simultaneous runs of a large number of random walks may lead to
//! the problem of congestion in the network"* — [`CongestionReport`]
//! records the peak number of walkers resident on a single page per hop.

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Congestion metrics for one round of simultaneous walks.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CongestionReport {
    /// Peak walkers on any single page at any hop.
    pub peak_page_load: usize,
    /// Total hops taken (network messages).
    pub total_hops: usize,
    /// Number of hops until all walks terminated.
    pub rounds_to_drain: usize,
}

/// Monte-Carlo PageRank estimator.
#[derive(Debug, Clone)]
pub struct MonteCarlo<'g> {
    graph: &'g Graph,
    alpha: f64,
    visits: Vec<u64>,
    rounds: u64,
    last_congestion: CongestionReport,
}

impl<'g> MonteCarlo<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        MonteCarlo {
            graph,
            alpha,
            visits: vec![0; graph.n()],
            rounds: 0,
            last_congestion: CongestionReport::default(),
        }
    }

    /// Run one round: a walk starts at *every* page simultaneously (the
    /// \[9\] scheme); each walk counts its start, then repeatedly moves to
    /// a uniform out-neighbour with probability α or terminates. All
    /// walks advance in lockstep so page loads per hop are measurable.
    pub fn round(&mut self, rng: &mut Rng) -> CongestionReport {
        let n = self.graph.n();
        let mut frontier: Vec<u32> = (0..n as u32).collect();
        let mut report = CongestionReport::default();
        // Initial placement: one walker everywhere.
        report.peak_page_load = 1;
        for &p in &frontier {
            self.visits[p as usize] += 1;
        }
        let mut load = vec![0u32; n];
        while !frontier.is_empty() {
            report.rounds_to_drain += 1;
            let mut next: Vec<u32> = Vec::with_capacity(frontier.len());
            for &p in &frontier {
                if rng.bernoulli(self.alpha) {
                    let out = self.graph.out(p as usize);
                    // A dangling page carries the shared implicit
                    // self-loop: the walk parks there (no neighbour
                    // draw), matching the repaired hyperlink matrix the
                    // exact reference is computed from.
                    let dst = if out.is_empty() { p } else { out[rng.below(out.len())] };
                    self.visits[dst as usize] += 1;
                    report.total_hops += 1;
                    next.push(dst);
                }
            }
            load.iter_mut().for_each(|v| *v = 0);
            for &p in &next {
                load[p as usize] += 1;
            }
            let peak = load.iter().copied().max().unwrap_or(0) as usize;
            report.peak_page_load = report.peak_page_load.max(peak);
            frontier = next;
        }
        self.rounds += 1;
        self.last_congestion = report.clone();
        report
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    pub fn last_congestion(&self) -> &CongestionReport {
        &self.last_congestion
    }
}

impl<'g> PageRankSolver for MonteCarlo<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    /// One solver step = one full round of walks (so trajectories are
    /// comparable per unit of communication, use `total_hops`).
    fn step(&mut self, rng: &mut Rng) -> StepStats {
        let rep = self.round(rng);
        StepStats {
            reads: rep.total_hops,
            writes: rep.total_hops,
            activated: self.graph.n(),
        }
    }

    /// `x̂ = (1-α) visits / R` (scaled normalization).
    fn estimate(&self) -> Vec<f64> {
        if self.rounds == 0 {
            return vec![0.0; self.graph.n()];
        }
        let scale = (1.0 - self.alpha) / self.rounds as f64;
        self.visits.iter().map(|&v| v as f64 * scale).collect()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        if self.rounds == 0 {
            return x_star.iter().map(|v| v * v).sum();
        }
        let scale = (1.0 - self.alpha) / self.rounds as f64;
        self.visits
            .iter()
            .zip(x_star)
            .map(|(&v, &s)| {
                let d = v as f64 * scale - s;
                d * d
            })
            .sum()
    }

    fn name(&self) -> &'static str {
        "monte-carlo walks [9]"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn estimator_is_unbiased_ish() {
        let g = generators::er_threshold(30, 0.5, 81);
        let x_star = exact_pagerank(&g, 0.85);
        let mut mc = MonteCarlo::new(&g, 0.85);
        let mut rng = Rng::seeded(82);
        for _ in 0..3000 {
            mc.round(&mut rng);
        }
        let est = mc.estimate();
        // Monte-Carlo error ~ 1/sqrt(3000) per entry; generous tolerance.
        let err = vector::dist_inf(&est, &x_star);
        assert!(err < 0.15, "err={err}");
        // mean over pages should be very close to 1 (scaled normalization)
        let mean = vector::sum(&est) / 30.0;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn convergence_is_sqrt_r() {
        // Error after 4x the rounds should be ~2x smaller (not 16x):
        // that's the sub-exponential 1/sqrt(R) signature.
        let g = generators::er_threshold(25, 0.5, 83);
        let x_star = exact_pagerank(&g, 0.85);
        let run = |rounds: usize, seed: u64| {
            let mut mc = MonteCarlo::new(&g, 0.85);
            let mut rng = Rng::seeded(seed);
            for _ in 0..rounds {
                mc.round(&mut rng);
            }
            vector::dist_sq(&mc.estimate(), &x_star) / 25.0
        };
        // average over a few seeds to tame noise
        let e_small: f64 = (0..5).map(|s| run(200, 84 + s)).sum::<f64>() / 5.0;
        let e_big: f64 = (0..5).map(|s| run(3200, 90 + s)).sum::<f64>() / 5.0;
        let ratio = e_small / e_big;
        // 16x rounds -> ~16x smaller squared error (variance scaling);
        // exponential would give many orders of magnitude.
        assert!(ratio > 4.0 && ratio < 80.0, "ratio={ratio}");
    }

    #[test]
    fn congestion_reported() {
        let g = generators::star(20); // everything funnels through the hub
        let mut mc = MonteCarlo::new(&g, 0.85);
        let mut rng = Rng::seeded(85);
        let rep = mc.round(&mut rng);
        assert!(rep.peak_page_load > 1, "star hub must congest: {rep:?}");
        assert!(rep.total_hops > 0);
        assert_eq!(mc.last_congestion(), &rep);
    }

    #[test]
    fn walk_lengths_geometric() {
        // Expected hops per walk = α/(1-α) ≈ 5.67 at α=0.85.
        let g = generators::er_threshold(20, 0.5, 86);
        let mut mc = MonteCarlo::new(&g, 0.85);
        let mut rng = Rng::seeded(87);
        let mut hops = 0usize;
        let rounds = 500;
        for _ in 0..rounds {
            hops += mc.round(&mut rng).total_hops;
        }
        let per_walk = hops as f64 / (rounds * 20) as f64;
        assert!((per_walk - 0.85 / 0.15).abs() < 0.3, "per_walk={per_walk}");
    }

    #[test]
    fn dangling_chain_walks_park_at_the_sink() {
        // chain(12)'s last page has no out-links; the self-loop parks
        // walkers instead of panicking on an empty neighbour draw, and
        // the estimate matches the repaired-matrix reference.
        let g = generators::chain(12);
        let x_star = exact_pagerank(&g, 0.85);
        let mut mc = MonteCarlo::new(&g, 0.85);
        let mut rng = Rng::seeded(88);
        for _ in 0..4000 {
            mc.round(&mut rng);
        }
        let est = mc.estimate();
        assert!(est.iter().all(|v| v.is_finite()));
        let err = vector::dist_inf(&est, &x_star);
        assert!(err < 0.2, "err={err}");
    }

    #[test]
    fn zero_rounds_estimate_is_zero() {
        let g = generators::ring(5);
        let mc = MonteCarlo::new(&g, 0.85);
        assert_eq!(mc.estimate(), vec![0.0; 5]);
    }
}
