//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! Pipeline (see /opt/xla-example and DESIGN.md §2):
//!
//! 1. `make artifacts` runs Python **once**: `python/compile/aot.py`
//!    lowers the L2 scan graphs (whose inner ops are the L1 Pallas
//!    kernels, `interpret=True`) to **HLO text** — the interchange format
//!    the bundled xla_extension 0.5.1 accepts (jax ≥ 0.5 serialized
//!    protos carry 64-bit instruction ids it rejects).
//! 2. [`client::Engine`] parses `manifest.json`, compiles each HLO module
//!    on the PJRT CPU client once, and caches the executables.
//! 3. [`executor`] exposes typed entry points (`MpChunkRunner`, …) that
//!    pad f64 state to the artifact's f32 padded shapes ([`pad`]),
//!    execute, and un-pad.
//!
//! Python never runs at request time: after `make artifacts` the Rust
//! binary is self-contained.

pub mod artifacts;
pub mod client;
pub mod executor;
pub mod pad;

// The PJRT bindings: the real `xla` crate when the `pjrt` feature is on
// (add it as a path dependency pointing at the rust_pallas toolchain's
// crate), otherwise the offline stub that errors on first use so the rest
// of the system builds and tests without the toolchain.
#[cfg(feature = "pjrt")]
pub(crate) use xla as xla_compat;
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub(crate) mod xla_compat;

pub use artifacts::{ArtifactKind, ArtifactSpec, Manifest};
pub use client::Engine;
pub use executor::{JacobiRunner, MpChunkRunner, ResidualNormRunner, SizeChunkRunner};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$PAGERANK_MP_ARTIFACTS` if set, else
/// `artifacts/` relative to the current directory, else relative to the
/// crate root (useful under `cargo test`).
pub fn artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PAGERANK_MP_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACT_DIR);
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT_DIR)
}
