//! Padding rules — the Rust mirror of `python/compile/model.py`.
//!
//! Artifacts are compiled at fixed padded sizes P (multiples of the L1
//! kernel block). A graph of N ≤ P pages maps in as:
//!
//! * `A_pad = blockdiag(A, I)` (padded pages are self-loops, column
//!   stochastic), hence `B_pad = blockdiag(B, (1-α)I)`;
//! * vectors zero-pad;
//! * activation sequences only index real pages, so padded coordinates
//!   are exactly inert (pinned by tests on both language sides).
//!
//! Everything crosses the boundary as **row-major f32** (the layout
//! `xla::Literal::vec1(..).reshape(..)` produces).

use crate::graph::Graph;
use crate::linalg::sparse::BColumns;

/// Row-major padded matrices/vectors for one (graph, alpha, P) binding.
#[derive(Debug, Clone)]
pub struct PaddedProblem {
    pub n: usize,
    pub p: usize,
    pub alpha: f64,
    /// Row-major (P,P) hyperlink matrix with identity padding.
    pub a_pad: Vec<f32>,
    /// Row-major (P,P) B = I - alpha*A_pad.
    pub b_pad: Vec<f32>,
    /// (P,1) per-column squared norms of B_pad.
    pub bnorm2: Vec<f32>,
    /// Row-major (P,P) C^T = I - A_pad (for Algorithm 2).
    pub ct_pad: Vec<f32>,
    /// (P,1) ||C(k,:)||^2 with padded rows clamped to 1 (guard against
    /// 0/0; they are never activated).
    pub cnorm2: Vec<f32>,
    /// (P,1) y = (1-alpha) on real coordinates, 0 on padding.
    pub y: Vec<f32>,
    /// (P,1) target s = 1/N on real coordinates, 0 on padding.
    pub s_target: Vec<f32>,
}

impl PaddedProblem {
    pub fn new(graph: &Graph, alpha: f64, p: usize) -> PaddedProblem {
        let n = graph.n();
        assert!(p >= n, "padded size {p} < graph size {n}");
        let mut a_pad = vec![0.0f32; p * p];
        // Real block: A[i][j] = 1/N_j iff j -> i.
        for j in 0..n {
            let w = 1.0 / graph.out_degree(j) as f64;
            for &i in graph.out(j) {
                a_pad[(i as usize) * p + j] = w as f32;
            }
        }
        // Identity padding.
        for d in n..p {
            a_pad[d * p + d] = 1.0;
        }
        // B = I - alpha A (f32, row-major).
        let mut b_pad = vec![0.0f32; p * p];
        for i in 0..p {
            for j in 0..p {
                let idij = if i == j { 1.0f32 } else { 0.0 };
                b_pad[i * p + j] = idij - (alpha as f32) * a_pad[i * p + j];
            }
        }
        // Column norms of B_pad — from the closed form for real columns
        // (BColumns, f64 precision) and (1-alpha)^2 for padding.
        let cols = BColumns::new(graph, alpha);
        let mut bnorm2 = vec![0.0f32; p];
        for k in 0..n {
            bnorm2[k] = cols.norm_sq(k) as f32;
        }
        let pad_b = ((1.0 - alpha) * (1.0 - alpha)) as f32;
        bnorm2[n..p].iter_mut().for_each(|v| *v = pad_b);

        // C^T = I - A_pad; padded block is I - I = 0.
        let mut ct_pad = vec![0.0f32; p * p];
        for i in 0..p {
            for j in 0..p {
                let idij = if i == j { 1.0f32 } else { 0.0 };
                ct_pad[i * p + j] = idij - a_pad[i * p + j];
            }
        }
        // ||C(k,:)||^2 = 1 - 2 A_kk + 1/N_k for real rows; 1.0 guard on pads.
        let mut cnorm2 = vec![1.0f32; p];
        for k in 0..n {
            let nk = graph.out_degree(k) as f64;
            let akk = if graph.has_self_loop(k) { 1.0 / nk } else { 0.0 };
            cnorm2[k] = (1.0 - 2.0 * akk + 1.0 / nk) as f32;
        }

        let mut y = vec![0.0f32; p];
        y[..n].iter_mut().for_each(|v| *v = (1.0 - alpha) as f32);
        let mut s_target = vec![0.0f32; p];
        s_target[..n].iter_mut().for_each(|v| *v = (1.0 / n as f64) as f32);

        PaddedProblem {
            n,
            p,
            alpha,
            a_pad,
            b_pad,
            bnorm2,
            ct_pad,
            cnorm2,
            y,
            s_target,
        }
    }
}

/// Zero-pad an f64 vector to a (P,) f32 buffer.
pub fn pad_vec(v: &[f64], p: usize) -> Vec<f32> {
    assert!(v.len() <= p);
    let mut out = vec![0.0f32; p];
    for (o, &x) in out.iter_mut().zip(v) {
        *o = x as f32;
    }
    out
}

/// Truncate a (P,) f32 buffer back to n f64 entries.
pub fn unpad_vec(v: &[f32], n: usize) -> Vec<f64> {
    assert!(n <= v.len());
    v[..n].iter().map(|&x| x as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;

    #[test]
    fn padded_a_matches_python_rules() {
        let g = generators::er_threshold(20, 0.5, 171);
        let pp = PaddedProblem::new(&g, 0.85, 32);
        // Real block equals the dense hyperlink matrix.
        let a = DenseMatrix::hyperlink(&g);
        for i in 0..20 {
            for j in 0..20 {
                assert!((pp.a_pad[i * 32 + j] as f64 - a.get(i, j)).abs() < 1e-7);
            }
        }
        // Identity padding, zero off-blocks.
        for d in 20..32 {
            assert_eq!(pp.a_pad[d * 32 + d], 1.0);
        }
        assert_eq!(pp.a_pad[5 * 32 + 25], 0.0);
        assert_eq!(pp.a_pad[25 * 32 + 5], 0.0);
        // Columns all sum to 1.
        for j in 0..32 {
            let s: f32 = (0..32).map(|i| pp.a_pad[i * 32 + j]).sum();
            assert!((s - 1.0).abs() < 1e-5, "col {j} sums {s}");
        }
    }

    #[test]
    fn padded_b_and_norms() {
        let g = generators::er_threshold(20, 0.5, 172);
        let alpha = 0.85;
        let pp = PaddedProblem::new(&g, alpha, 32);
        let b = DenseMatrix::b_matrix(&g, alpha);
        for i in 0..20 {
            for j in 0..20 {
                assert!((pp.b_pad[i * 32 + j] as f64 - b.get(i, j)).abs() < 1e-6);
            }
        }
        // Padded column norms = (1-alpha)^2.
        for k in 20..32 {
            assert!((pp.bnorm2[k] - 0.15f32 * 0.15).abs() < 1e-7);
        }
        // Real norms match dense computation.
        let n2 = b.column_norms_sq();
        for k in 0..20 {
            assert!((pp.bnorm2[k] as f64 - n2[k]).abs() < 1e-5);
        }
    }

    #[test]
    fn ct_pad_rows_are_c_rows() {
        let g = generators::er_threshold(15, 0.5, 173);
        let pp = PaddedProblem::new(&g, 0.85, 16);
        let a = DenseMatrix::hyperlink(&g);
        // (C^T)[i][j] = (I - A)[i][j]; row k of C is column k of I - A.
        for i in 0..15 {
            for j in 0..15 {
                let want = if i == j { 1.0 } else { 0.0 } - a.get(i, j);
                assert!((pp.ct_pad[i * 16 + j] as f64 - want).abs() < 1e-6);
            }
        }
        // Padded C^T block is zero; guard norms are 1.
        assert_eq!(pp.ct_pad[15 * 16 + 15], 0.0);
        assert_eq!(pp.cnorm2[15], 1.0);
    }

    #[test]
    fn vectors_round_trip() {
        let v = vec![1.5, -2.25, 3.0];
        let padded = pad_vec(&v, 8);
        assert_eq!(padded.len(), 8);
        assert_eq!(padded[3..], [0.0; 5]);
        let back = unpad_vec(&padded, 3);
        assert_eq!(back, v);
    }

    #[test]
    #[should_panic]
    fn pad_rejects_small_p() {
        let g = generators::ring(10);
        PaddedProblem::new(&g, 0.85, 5);
    }
}
