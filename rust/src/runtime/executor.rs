//! Typed runners over the AOT artifacts.
//!
//! Each runner owns the padded problem binding plus device-resident
//! state, converts f64 ↔ f32 at the boundary, and drives
//! [`super::client::Engine`]. The dense engine's role in the system is
//! documented in DESIGN.md §2: reference solving, batched-dense
//! cross-validation of the sparse Rust path, and the Pallas hot-spot
//! demonstration.
//!
//! Hot-path design (EXPERIMENTS.md §Perf): the constant O(P²) matrices
//! are uploaded once at construction and stay device-resident; per chunk
//! only the O(P) state and O(T) activation sequence cross the boundary.
//! (Fully device-resident state is blocked by the 0.5.1 PJRT client
//! returning results as a single tuple buffer — see the §Perf log.)

use crate::anyhow;
use crate::util::error::Result;

use crate::graph::Graph;

use super::artifacts::{ArtifactKind, ArtifactSpec};
use super::client::{to_vec_f32, Engine};
use super::pad::{pad_vec, unpad_vec, PaddedProblem};
use super::xla_compat as xla;

/// State shared by the runners for one (graph, alpha) binding.
struct Binding {
    spec: ArtifactSpec,
    pp: PaddedProblem,
}

impl Binding {
    fn new(engine: &Engine, kind: ArtifactKind, graph: &Graph, alpha: f64) -> Result<Binding> {
        let spec = engine.select(kind, graph.n())?;
        let pp = PaddedProblem::new(graph, alpha, spec.padded_size);
        Ok(Binding { spec, pp })
    }
}

/// Runs `mp_chunk` artifacts: T Algorithm-1 steps per call on dense
/// padded B, returning the per-step `‖r‖²` trace.
pub struct MpChunkRunner {
    binding: Binding,
    /// Host-mirrored evolving state (f32, padded).
    x: Vec<f32>,
    r: Vec<f32>,
    /// Persistent device buffers for the constant matrix inputs.
    b_buf: xla::PjRtBuffer,
    bn_buf: xla::PjRtBuffer,
}

impl MpChunkRunner {
    pub fn new(engine: &mut Engine, graph: &Graph, alpha: f64) -> Result<MpChunkRunner> {
        let binding = Binding::new(engine, ArtifactKind::MpChunk, graph, alpha)?;
        let p = binding.pp.p;
        let b_buf = engine.upload_f32(&binding.pp.b_pad, &[p, p])?;
        let bn_buf = engine.upload_f32(&binding.pp.bnorm2, &[p, 1])?;
        let x = vec![0.0f32; p];
        let r = binding.pp.y.clone();
        // Warm the executable cache so run() latency is pure execution.
        engine.executable(&binding.spec)?;
        Ok(MpChunkRunner { binding, x, r, b_buf, bn_buf })
    }

    /// Chunk length T compiled into the artifact.
    pub fn chunk_len(&self) -> usize {
        self.binding.spec.chunk.expect("mp_chunk has a chunk length")
    }

    pub fn padded_size(&self) -> usize {
        self.binding.pp.p
    }

    /// Run exactly `chunk_len` activations given by `ks` (real-page
    /// indices); returns the per-step `‖r_t‖²` trace.
    pub fn run_chunk(&mut self, engine: &mut Engine, ks: &[usize]) -> Result<Vec<f64>> {
        let t = self.chunk_len();
        if ks.len() != t {
            return Err(anyhow!("expected {} activations, got {}", t, ks.len()));
        }
        let n = self.binding.pp.n;
        if let Some(&bad) = ks.iter().find(|&&k| k >= n) {
            return Err(anyhow!("activation {bad} out of range (n={n})"));
        }
        let p = self.binding.pp.p;
        let ks_i32: Vec<i32> = ks.iter().map(|&k| k as i32).collect();
        let x_buf = engine.upload_f32(&self.x, &[p, 1])?;
        let r_buf = engine.upload_f32(&self.r, &[p, 1])?;
        let ks_buf = engine.upload_i32(&ks_i32, &[t])?;
        let outs = engine.execute_buffers(
            &self.binding.spec,
            &[&self.b_buf, &self.bn_buf, &x_buf, &r_buf, &ks_buf],
        )?;
        self.x = to_vec_f32(&outs[0])?;
        self.r = to_vec_f32(&outs[1])?;
        let trace = to_vec_f32(&outs[2])?;
        Ok(trace.iter().map(|&v| v as f64).collect())
    }

    /// Current estimate, un-padded (f64).
    pub fn estimate(&self) -> Vec<f64> {
        unpad_vec(&self.x, self.binding.pp.n)
    }

    /// Current residual, un-padded (f64).
    pub fn residual(&self) -> Vec<f64> {
        unpad_vec(&self.r, self.binding.pp.n)
    }

    /// Padded tail of the state — must stay exactly zero (inertness).
    pub fn padding_tail_abs_max(&self) -> f32 {
        let n = self.binding.pp.n;
        self.x[n..]
            .iter()
            .chain(self.r[n..].iter())
            .fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

/// Runs `jacobi_chunk` artifacts: T centralized fixed-point sweeps per
/// call (`x ← αAx + y`).
pub struct JacobiRunner {
    binding: Binding,
    x: Vec<f32>,
    a_buf: xla::PjRtBuffer,
    y_buf: xla::PjRtBuffer,
    alpha_buf: xla::PjRtBuffer,
}

impl JacobiRunner {
    pub fn new(engine: &mut Engine, graph: &Graph, alpha: f64) -> Result<JacobiRunner> {
        let binding = Binding::new(engine, ArtifactKind::JacobiChunk, graph, alpha)?;
        let p = binding.pp.p;
        let a_buf = engine.upload_f32(&binding.pp.a_pad, &[p, p])?;
        let y_buf = engine.upload_f32(&binding.pp.y, &[p, 1])?;
        let alpha_buf = engine.upload_f32(&[alpha as f32], &[1, 1])?;
        engine.executable(&binding.spec)?;
        Ok(JacobiRunner { x: vec![0.0f32; p], binding, a_buf, y_buf, alpha_buf })
    }

    /// Sweeps per call.
    pub fn chunk_len(&self) -> usize {
        self.binding.spec.chunk.expect("jacobi_chunk has a chunk length")
    }

    /// Run one chunk of sweeps.
    pub fn run_chunk(&mut self, engine: &mut Engine) -> Result<()> {
        let p = self.binding.pp.p;
        let x_buf = engine.upload_f32(&self.x, &[p, 1])?;
        let outs = engine.execute_buffers(
            &self.binding.spec,
            &[&self.a_buf, &x_buf, &self.y_buf, &self.alpha_buf],
        )?;
        self.x = to_vec_f32(&outs[0])?;
        Ok(())
    }

    /// Run chunks until the estimate moves less than `tol` (l∞) between
    /// chunks, up to `max_chunks`. Returns chunks executed.
    pub fn run_to_tolerance(
        &mut self,
        engine: &mut Engine,
        tol: f64,
        max_chunks: usize,
    ) -> Result<usize> {
        for c in 0..max_chunks {
            let prev = self.x.clone();
            self.run_chunk(engine)?;
            let delta = prev
                .iter()
                .zip(&self.x)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if (delta as f64) < tol {
                return Ok(c + 1);
            }
        }
        Ok(max_chunks)
    }

    pub fn estimate(&self) -> Vec<f64> {
        unpad_vec(&self.x, self.binding.pp.n)
    }
}

/// Runs `size_chunk` artifacts: T Algorithm-2 steps per call, returning
/// the `‖s_t - s‖²` trace (Fig. 2's quantity).
pub struct SizeChunkRunner {
    binding: Binding,
    s: Vec<f32>,
    ct_buf: xla::PjRtBuffer,
    cn_buf: xla::PjRtBuffer,
    tgt_buf: xla::PjRtBuffer,
}

impl SizeChunkRunner {
    pub fn new(engine: &mut Engine, graph: &Graph) -> Result<SizeChunkRunner> {
        // alpha is irrelevant for C = (I-A)^T; reuse the padding binding.
        let binding = Binding::new(engine, ArtifactKind::SizeChunk, graph, 0.85)?;
        let p = binding.pp.p;
        let ct_buf = engine.upload_f32(&binding.pp.ct_pad, &[p, p])?;
        let cn_buf = engine.upload_f32(&binding.pp.cnorm2, &[p, 1])?;
        let tgt_buf = engine.upload_f32(&binding.pp.s_target, &[p, 1])?;
        // s_0 = e_1 (the paper's initialization).
        let mut s = vec![0.0f32; p];
        s[0] = 1.0;
        engine.executable(&binding.spec)?;
        Ok(SizeChunkRunner { binding, s, ct_buf, cn_buf, tgt_buf })
    }

    pub fn chunk_len(&self) -> usize {
        self.binding.spec.chunk.expect("size_chunk has a chunk length")
    }

    pub fn run_chunk(&mut self, engine: &mut Engine, ks: &[usize]) -> Result<Vec<f64>> {
        let t = self.chunk_len();
        if ks.len() != t {
            return Err(anyhow!("expected {} activations, got {}", t, ks.len()));
        }
        let n = self.binding.pp.n;
        if let Some(&bad) = ks.iter().find(|&&k| k >= n) {
            return Err(anyhow!("activation {bad} out of range (n={n})"));
        }
        let p = self.binding.pp.p;
        let ks_i32: Vec<i32> = ks.iter().map(|&k| k as i32).collect();
        let s_buf = engine.upload_f32(&self.s, &[p, 1])?;
        let ks_buf = engine.upload_i32(&ks_i32, &[t])?;
        let outs = engine.execute_buffers(
            &self.binding.spec,
            &[&self.ct_buf, &self.cn_buf, &s_buf, &self.tgt_buf, &ks_buf],
        )?;
        self.s = to_vec_f32(&outs[0])?;
        let trace = to_vec_f32(&outs[1])?;
        Ok(trace.iter().map(|&v| v as f64).collect())
    }

    pub fn s(&self) -> Vec<f64> {
        unpad_vec(&self.s, self.binding.pp.n)
    }
}

/// Runs `residual_norm`: `(r, ‖r‖²) = (y - Bx, ...)` — the eq. 11
/// conservation checker on the dense engine.
pub struct ResidualNormRunner {
    binding: Binding,
    b_buf: xla::PjRtBuffer,
    y_buf: xla::PjRtBuffer,
}

impl ResidualNormRunner {
    pub fn new(engine: &mut Engine, graph: &Graph, alpha: f64) -> Result<ResidualNormRunner> {
        let binding = Binding::new(engine, ArtifactKind::ResidualNorm, graph, alpha)?;
        let p = binding.pp.p;
        let b_buf = engine.upload_f32(&binding.pp.b_pad, &[p, p])?;
        let y_buf = engine.upload_f32(&binding.pp.y, &[p, 1])?;
        engine.executable(&binding.spec)?;
        Ok(ResidualNormRunner { binding, b_buf, y_buf })
    }

    /// Evaluate `(r, ‖r‖²)` for an arbitrary estimate `x` (f64, length n).
    pub fn run(&self, engine: &mut Engine, x: &[f64]) -> Result<(Vec<f64>, f64)> {
        let p = self.binding.pp.p;
        if x.len() != self.binding.pp.n {
            return Err(anyhow!("x has {} entries, graph has {}", x.len(), self.binding.pp.n));
        }
        let x_buf = engine.upload_f32(&pad_vec(x, p), &[p, 1])?;
        let outs =
            engine.execute_buffers(&self.binding.spec, &[&self.b_buf, &x_buf, &self.y_buf])?;
        let r = unpad_vec(&to_vec_f32(&outs[0])?, self.binding.pp.n);
        let rn2 = to_vec_f32(&outs[1])?[0] as f64;
        Ok((r, rn2))
    }
}
