//! PJRT engine: compile-once cache of the AOT artifacts.
//!
//! Adapted from /opt/xla-example/src/bin/load_hlo.rs: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile`. Compilation happens once per (kind, size);
//! executions reuse the cached `PjRtLoadedExecutable`.

use std::collections::HashMap;

use crate::anyhow;
use crate::util::error::{Context, Result};

use super::artifacts::{ArtifactKind, ArtifactSpec, Manifest};
use super::xla_compat as xla;

/// Compiled-executable cache over one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<(ArtifactKind, usize), xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn load(dir: &std::path::Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)
            .map_err(|e| anyhow!("loading manifest from {}: {e}", dir.display()))?;
        Ok(Engine {
            client,
            manifest,
            compiled: HashMap::new(),
        })
    }

    /// Load from the default artifact directory (`make artifacts` output).
    pub fn load_default() -> Result<Engine> {
        Self::load(&super::artifact_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Select the smallest artifact of `kind` fitting `n` pages.
    pub fn select(&self, kind: ArtifactKind, n: usize) -> Result<ArtifactSpec> {
        self.manifest
            .select(kind, n)
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "no {} artifact fits n={n} (available sizes: {:?}) — re-run \
                     `make artifacts` with larger --sizes",
                    kind.name(),
                    self.manifest.sizes_for(kind)
                )
            })
    }

    /// Get (compiling and caching on first use) the executable for a spec.
    pub fn executable(
        &mut self,
        spec: &ArtifactSpec,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (spec.kind, spec.padded_size);
        if !self.compiled.contains_key(&key) {
            let path = self.manifest.path_of(spec);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.file))?;
            self.compiled.insert(key, exe);
        }
        Ok(self.compiled.get(&key).expect("just inserted"))
    }

    /// Execute an artifact with literal inputs; returns the decomposed
    /// result tuple (aot.py lowers with return_tuple=True; the 0.5.1 PJRT
    /// client yields the tuple as a single buffer).
    pub fn execute(
        &mut self,
        spec: &ArtifactSpec,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != spec.operands.len() {
            return Err(anyhow!(
                "{}: expected {} operands, got {}",
                spec.file,
                spec.operands.len(),
                inputs.len()
            ));
        }
        let exe = self.executable(spec)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", spec.file))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = out.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.results.len() {
            return Err(anyhow!(
                "{}: expected {} results, got {}",
                spec.file,
                spec.results.len(),
                parts.len()
            ));
        }
        Ok(parts)
    }

    /// Upload an f32 host buffer to the device (for buffer-resident reuse
    /// — the hot-path optimization; see EXPERIMENTS.md §Perf).
    ///
    /// NOTE: this deliberately uses `buffer_from_host_buffer`
    /// (HostBufferSemantics::kImmutableOnlyDuringCall — synchronous copy)
    /// and NOT `buffer_from_host_literal`: the TFRT CPU client implements
    /// the latter *asynchronously*, so dropping the source literal after
    /// the call is a use-after-free that corrupts transfers
    /// nondeterministically (observed as garbage literal sizes in
    /// ToLiteralSync — see EXPERIMENTS.md §Perf iteration log).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer to device")
    }

    /// Upload an i32 host buffer (activation sequences).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading i32 buffer to device")
    }

    /// Execute with pre-uploaded device buffers for the constant (large)
    /// operands — the hot path: only the small evolving state crosses the
    /// host/device boundary per chunk (EXPERIMENTS.md §Perf).
    pub fn execute_buffers(
        &mut self,
        spec: &ArtifactSpec,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(spec)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing {} (buffers)", spec.file))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = out.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.results.len() {
            return Err(anyhow!(
                "{}: expected {} results, got {}",
                spec.file,
                spec.results.len(),
                parts.len()
            ));
        }
        Ok(parts)
    }
}

/// Build an f32 literal of the given dims from a row-major buffer.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let count: usize = dims.iter().product();
    if count != data.len() {
        return Err(anyhow!("literal shape {:?} != data len {}", dims, data.len()));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Build an i32 literal (activation sequences).
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let count: usize = dims.iter().product();
    if count != data.len() {
        return Err(anyhow!("literal shape {:?} != data len {}", dims, data.len()));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Extract an f32 literal into a Vec<f32>.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Download a device buffer into a Vec<f32>.
pub fn buffer_to_vec_f32(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync().context("downloading buffer")?;
    to_vec_f32(&lit)
}

// NOTE: engine tests live in rust/tests/runtime_e2e.rs — they need the
// artifacts built by `make artifacts` and a PJRT client, which is too
// heavy for unit scope.
