//! Offline stand-in for the `xla` PJRT bindings crate.
//!
//! The real crate ships with the rust_pallas toolchain (the
//! `/opt/xla-example` setup the runtime layer was written against) and is
//! not vendored in this repository. This stub keeps [`crate::runtime`]
//! compiling in the fully offline build and returns a descriptive error
//! the moment any PJRT entry point is exercised. Enable the `pjrt` cargo
//! feature — and add the local `xla` crate as a path dependency — to link
//! the real client (see [`crate::runtime`] module docs).

use crate::util::error::{Error, Result};

fn unavailable(what: &str) -> Error {
    Error::msg(format!(
        "{what}: PJRT runtime not linked (offline build without the `pjrt` \
         feature). Rebuild with `--features pjrt` and the rust_pallas \
         `xla` crate as a path dependency to enable the dense engine."
    ))
}

/// Stub of `xla::PjRtClient`.
#[derive(Debug)]
pub struct PjRtClient;

/// Stub of `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

/// Stub of `xla::PjRtBuffer`.
#[derive(Debug)]
pub struct PjRtBuffer;

/// Stub of `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal;

/// Stub of `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto;

/// Stub of `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub (pjrt feature disabled)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_error_with_guidance() {
        let err = PjRtClient::cpu().expect_err("stub must not pretend to work");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "error should name the feature: {msg}");
    }

    #[test]
    fn infallible_constructors_exist() {
        // These are reachable before any fallible call in the real flow.
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        let proto_err = HloModuleProto::from_text_file("nope.hlo.txt");
        assert!(proto_err.is_err());
    }
}
