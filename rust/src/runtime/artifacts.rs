//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `manifest.json` describing every lowered
//! HLO module: entry-point kind, padded size P, chunk length T, operand
//! and result shapes. The Rust runtime is driven entirely by the manifest
//! so Python and Rust cannot drift silently — shape mismatches fail at
//! load time with a named artifact.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// The four entry points emitted by aot.py.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    MpChunk,
    JacobiChunk,
    SizeChunk,
    ResidualNorm,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Option<ArtifactKind> {
        match s {
            "mp_chunk" => Some(ArtifactKind::MpChunk),
            "jacobi_chunk" => Some(ArtifactKind::JacobiChunk),
            "size_chunk" => Some(ArtifactKind::SizeChunk),
            "residual_norm" => Some(ArtifactKind::ResidualNorm),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArtifactKind::MpChunk => "mp_chunk",
            ArtifactKind::JacobiChunk => "jacobi_chunk",
            ArtifactKind::SizeChunk => "size_chunk",
            ArtifactKind::ResidualNorm => "residual_norm",
        }
    }
}

/// One operand/result shape record.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One lowered module.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub kind: ArtifactKind,
    pub file: String,
    pub padded_size: usize,
    /// Steps per call (None for residual_norm).
    pub chunk: Option<usize>,
    pub operands: Vec<TensorSpec>,
    pub results: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub block: usize,
    pub artifacts: Vec<ArtifactSpec>,
    dir: PathBuf,
}

/// Manifest loading errors.
#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Json(crate::util::json::JsonError),
    Schema(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "manifest io: {e}"),
            ManifestError::Json(e) => write!(f, "manifest json: {e}"),
            ManifestError::Schema(s) => write!(f, "manifest schema: {s}"),
        }
    }
}

impl std::error::Error for ManifestError {}

fn tensor_specs(v: &Json, what: &str) -> Result<Vec<TensorSpec>, ManifestError> {
    let arr = v
        .as_array()
        .ok_or_else(|| ManifestError::Schema(format!("{what} is not an array")))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError::Schema(format!("{what}: missing name")))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(Json::as_array)
                .ok_or_else(|| ManifestError::Schema(format!("{what}.{name}: missing shape")))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| ManifestError::Schema(format!("{what}.{name}: bad dim")))
                })
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = t
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError::Schema(format!("{what}.{name}: missing dtype")))?
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let text =
            std::fs::read_to_string(dir.join("manifest.json")).map_err(ManifestError::Io)?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, ManifestError> {
        let v = Json::parse(text).map_err(ManifestError::Json)?;
        let block = v
            .get("block")
            .and_then(Json::as_usize)
            .ok_or_else(|| ManifestError::Schema("missing block".into()))?;
        let arts = v
            .get("artifacts")
            .and_then(Json::as_array)
            .ok_or_else(|| ManifestError::Schema("missing artifacts".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let kind_str = a
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError::Schema("artifact missing kind".into()))?;
            let kind = ArtifactKind::parse(kind_str)
                .ok_or_else(|| ManifestError::Schema(format!("unknown kind {kind_str}")))?;
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| ManifestError::Schema("artifact missing file".into()))?
                .to_string();
            let padded_size = a
                .get("padded_size")
                .and_then(Json::as_usize)
                .ok_or_else(|| ManifestError::Schema(format!("{file}: missing padded_size")))?;
            let chunk = a.get("chunk").and_then(Json::as_usize);
            let operands = tensor_specs(
                a.get("operands")
                    .ok_or_else(|| ManifestError::Schema(format!("{file}: missing operands")))?,
                "operands",
            )?;
            let results = tensor_specs(
                a.get("results")
                    .ok_or_else(|| ManifestError::Schema(format!("{file}: missing results")))?,
                "results",
            )?;
            artifacts.push(ArtifactSpec {
                kind,
                file,
                padded_size,
                chunk,
                operands,
                results,
            });
        }
        Ok(Manifest {
            block,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Padded sizes available for a kind, ascending.
    pub fn sizes_for(&self, kind: ArtifactKind) -> Vec<usize> {
        let mut s: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.padded_size)
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    }

    /// Pick the smallest artifact of `kind` whose padded size fits `n`.
    pub fn select(&self, kind: ArtifactKind, n: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.padded_size >= n)
            .min_by_key(|a| a.padded_size)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "block": 128, "dtype": "f32",
      "artifacts": [
        {"kind": "mp_chunk", "file": "mp_chunk_p128_t128.hlo.txt",
         "padded_size": 128, "chunk": 128, "block": 128,
         "operands": [
           {"name": "b_pad", "shape": [128, 128], "dtype": "f32"},
           {"name": "ks", "shape": [128], "dtype": "i32"}],
         "results": [{"name": "x", "shape": [128, 1], "dtype": "f32"}]},
        {"kind": "mp_chunk", "file": "mp_chunk_p256_t128.hlo.txt",
         "padded_size": 256, "chunk": 128, "block": 128,
         "operands": [], "results": []},
        {"kind": "residual_norm", "file": "residual_norm_p128.hlo.txt",
         "padded_size": 128, "chunk": null, "block": 128,
         "operands": [], "results": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).expect("parses");
        assert_eq!(m.block, 128);
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].kind, ArtifactKind::MpChunk);
        assert_eq!(m.artifacts[0].chunk, Some(128));
        assert_eq!(m.artifacts[2].chunk, None);
        assert_eq!(m.artifacts[0].operands[1].dtype, "i32");
    }

    #[test]
    fn selection_picks_smallest_fitting() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).expect("parses");
        assert_eq!(
            m.select(ArtifactKind::MpChunk, 100).expect("fit").padded_size,
            128
        );
        assert_eq!(
            m.select(ArtifactKind::MpChunk, 129).expect("fit").padded_size,
            256
        );
        assert!(m.select(ArtifactKind::MpChunk, 1000).is_none());
        assert_eq!(m.sizes_for(ArtifactKind::MpChunk), vec![128, 256]);
    }

    #[test]
    fn path_resolution() {
        let m = Manifest::parse(SAMPLE, Path::new("/data/arts")).expect("parses");
        let p = m.path_of(&m.artifacts[0]);
        assert_eq!(p, PathBuf::from("/data/arts/mp_chunk_p128_t128.hlo.txt"));
    }

    #[test]
    fn schema_errors_are_descriptive() {
        let bad = r#"{"artifacts": []}"#;
        let e = Manifest::parse(bad, Path::new(".")).unwrap_err();
        assert!(e.to_string().contains("block"));
        let bad2 = r#"{"block": 128, "artifacts": [{"kind": "nope", "file": "x"}]}"#;
        let e2 = Manifest::parse(bad2, Path::new(".")).unwrap_err();
        assert!(e2.to_string().contains("nope"));
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration-ish: if `make artifacts` has run, the real manifest
        // must satisfy this schema.
        let dir = crate::runtime::artifact_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).expect("real manifest parses");
            assert!(!m.artifacts.is_empty());
            assert!(m.select(ArtifactKind::MpChunk, 100).is_some());
        }
    }
}
