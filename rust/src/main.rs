//! `pagerank-mp` — CLI for the distributed Matching-Pursuit PageRank
//! system (Dai & Freris, 2017).
//!
//! Subcommands:
//!
//! * `run-scenario` — run a declarative experiment from a JSON file
//!                  (the engine API: any graphs × any solvers or size
//!                  estimators), dumping the machine-readable
//!                  `BENCH_scenario.json`.
//! * `sweep`      — expand one scenario over a parameter grid (graph, n,
//!                  α, shards, batch, latency, …), run every cell, and
//!                  merge the reports into `BENCH_sweep.json`.
//! * `list-solvers` — print the engine's solver and estimator registries.
//! * `rank`       — compute PageRank for a graph (generated or from file)
//!                  with a chosen engine (sparse matrix-form, distributed
//!                  coordinator, dense PJRT, power iteration).
//! * `fig1`       — reproduce the paper's Figure 1 (writes CSV + plot).
//! * `fig2`       — reproduce the paper's Figure 2.
//! * `ablation`   — run the DESIGN.md §4 ablation studies.
//! * `size`       — Algorithm 2 network-size estimation demo.
//! * `graph-info` — degree/SCC statistics for a graph.
//! * `gen-corpus` — stream a deterministic synthetic webgraph corpus to
//!                  disk (the offline fallback of `scripts/fetch_webgraph`).
//! * `artifacts`  — inspect the AOT artifact manifest.

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::algo::power_iteration::JacobiPowerIteration;
use pagerank_mp::algo::size_estimation::SizeEstimator;
use pagerank_mp::algo::stopping::RankingCertifier;
use pagerank_mp::coordinator::{Coordinator, CoordinatorConfig, Mode, SamplerKind};
use pagerank_mp::engine::{EstimatorSpec, Scenario, SolverSpec, Sweep};
use pagerank_mp::graph::{generators, io as graph_io, DanglingPolicy, Graph};
use pagerank_mp::harness::{ablation, fig1, fig2, report};
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::network::LatencyModel;
use pagerank_mp::util::cli::Args;
use pagerank_mp::util::rng::Rng;

fn parse_dangling(s: &str) -> Result<DanglingPolicy, String> {
    pagerank_mp::engine::graph_spec::dangling_from_key(s)
        .ok_or_else(|| format!("bad --dangling {s:?} (error | selfloop | linkall)"))
}

fn load_graph(args: &Args) -> Result<Graph, String> {
    if let Some(path) = args.get("graph-file") {
        let path = path.to_string();
        let policy = parse_dangling(&args.get_str("dangling", "linkall"))?;
        let opts = graph_io::LoadOptions::new(policy).remap_ids(args.flag("remap-ids"));
        // --cache keeps a validated `.csrbin` sidecar next to the text
        // file, so repeat corpus runs skip the parse entirely.
        return if args.flag("cache") {
            graph_io::load_cached(&path, &opts)
        } else {
            graph_io::load_with(&path, &opts)
        }
        .map_err(|e| e.to_string());
    }
    let name = args.get_str("graph", "paper");
    let n = args.get_parse("n", 100usize).map_err(|e| e.to_string())?;
    let seed = args.get_parse("seed", 2017u64).map_err(|e| e.to_string())?;
    generators::by_name(&name, n, seed).ok_or_else(|| {
        format!("unknown graph family {name:?} (try: paper, er-sparse, ba, ws, sbm, ring, star, complete)")
    })
}

fn cmd_run_scenario(args: &Args) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.get("file").map(str::to_string))
        .ok_or("usage: pagerank-mp run-scenario <scenario.json> [--bench-out FILE] [--csv FILE] [--threads T]")?;
    let text = std::fs::read_to_string(&path).map_err(|e| format!("reading {path}: {e}"))?;
    let mut scenario = Scenario::from_json_str(&text).map_err(|e| format!("{path}: {e}"))?;
    if let Some(t) = args.get("threads") {
        scenario.threads = t.parse().map_err(|_| format!("bad --threads {t:?}"))?;
    }
    eprintln!(
        "running scenario {:?}: graph {}, {} experiment [{}], {} steps x {} rounds …",
        scenario.name,
        scenario.graph.key(),
        scenario.experiment.kind_key(),
        scenario.experiment.run_keys().join(", "),
        scenario.steps,
        scenario.rounds,
    );
    let result = scenario.run()?;
    println!("{}", result.render());

    println!("decay-rate ordering (fastest first):");
    for (i, (key, rate)) in result.rate_ordering().into_iter().enumerate() {
        println!("  #{} {:<40} rate/step {:.6}", i + 1, key, rate);
    }

    let bench_out = args.get_str("bench-out", "BENCH_scenario.json");
    result
        .write_bench_json(std::path::Path::new(&bench_out))
        .map_err(|e| format!("writing {bench_out}: {e}"))?;
    println!("\nwrote {bench_out}");
    if let Some(csv) = args.get("csv") {
        let csv = csv.to_string();
        report::write_file(std::path::Path::new(&csv), &result.to_csv())
            .map_err(|e| format!("writing {csv}: {e}"))?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.get("file").map(str::to_string))
        .ok_or("usage: pagerank-mp sweep <sweep.json> [--bench-out BENCH_sweep.json] [--threads T]")?;
    let text = std::fs::read_to_string(&path).map_err(|e| format!("reading {path}: {e}"))?;
    let mut sweep = Sweep::from_json_str(&text).map_err(|e| format!("{path}: {e}"))?;
    if let Some(t) = args.get("threads") {
        sweep.base.threads = t.parse().map_err(|_| format!("bad --threads {t:?}"))?;
    }
    eprintln!(
        "sweep {:?}: {} cells over axes [{}], {} experiment [{}]",
        sweep.name,
        sweep.cell_count(),
        sweep.axes.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>().join(", "),
        sweep.base.experiment.kind_key(),
        sweep.base.experiment.run_keys().join(", "),
    );
    let report = sweep.run_with_progress(|i, total, name| {
        eprintln!("  cell {i}/{total}: {name} …");
    })?;
    println!("{}", report.render());
    let bench_out = args.get_str("bench-out", "BENCH_sweep.json");
    report
        .write_bench_json(std::path::Path::new(&bench_out))
        .map_err(|e| format!("writing {bench_out}: {e}"))?;
    println!("\nwrote {bench_out}");
    Ok(())
}

fn cmd_list_solvers(_args: &Args) -> Result<(), String> {
    println!("solver registry (engine::SolverSpec) — use these names in scenario JSON:\n");
    for spec in SolverSpec::all() {
        println!("  {:<44} {}", spec.key(), spec.describe());
    }
    println!(
        "\nparameterized forms: mp:residual[:<floor>], parallel-mp:<batch>, \
         sharded:<shards>[:<batch>[:<mod|block|cluster|scc>[:<leader|worker>[:<uniform|residual>]]]], \
         msgpass:<shards>[:<batch>[:<mod|block|cluster|scc>[:<gossip-period>]]]\
         [:drop<p>][:crash<shard>@<at>+<down-for>][:rel|raw], \
         coordinator:<sequential|async>:<uniform|clocks|weighted>:<zero|const:L|uniform:lo:hi|exp:mean>"
    );
    println!(
        "\nestimator registry (engine::EstimatorSpec) — \
         \"experiment\": {{\"kind\": \"size-estimation\", \"estimators\": [...]}}:\n"
    );
    for spec in EstimatorSpec::all() {
        println!("  {:<44} {}", spec.key(), spec.describe());
    }
    Ok(())
}

fn cmd_rank(args: &Args) -> Result<(), String> {
    let g = load_graph(args)?;
    let alpha = args.get_parse("alpha", 0.85f64).map_err(|e| e.to_string())?;
    let steps = args.get_parse("steps", 100_000usize).map_err(|e| e.to_string())?;
    let seed = args.get_parse("seed", 2017u64).map_err(|e| e.to_string())?;
    let top = args.get_parse("top", 10usize).map_err(|e| e.to_string())?;
    let engine = args.get_str("engine", "sparse");

    let start = std::time::Instant::now();
    let (x, label): (Vec<f64>, String) = match engine.as_str() {
        "sparse" => {
            let mut mp = MatchingPursuit::new(&g, alpha);
            let mut rng = Rng::seeded(seed);
            for _ in 0..steps {
                mp.step(&mut rng);
            }
            // Certified ranking prefix via the stopping criterion (§IV-4).
            let cert = RankingCertifier::new(&g, alpha);
            let c = cert.certify(&mp.estimate(), mp.residual_norm_sq());
            println!(
                "certified prefix {} pages (eps={:.2e})",
                c.certified_prefix, c.epsilon
            );
            (mp.estimate(), format!("sparse MP, {steps} activations"))
        }
        "coordinator" => {
            let latency = LatencyModel::parse(&args.get_str("latency", "zero"))
                .ok_or("bad --latency (zero|const:L|uniform:lo:hi|exp:mean)")?;
            let mode = match args.get_str("mode", "sequential").as_str() {
                "sequential" => Mode::Sequential,
                "async" => Mode::Async,
                m => return Err(format!("bad --mode {m}")),
            };
            let sampler = match args.get_str("sampler", "uniform").as_str() {
                "uniform" => SamplerKind::Uniform,
                "clocks" => SamplerKind::ExponentialClocks,
                "weighted" => SamplerKind::ResidualWeighted { floor: 1e-12 },
                s => return Err(format!("bad --sampler {s}")),
            };
            let cfg = CoordinatorConfig::default()
                .with_alpha(alpha)
                .with_seed(seed)
                .with_latency(latency)
                .with_mode(mode)
                .with_sampler(sampler);
            let mut coord = Coordinator::new(&g, cfg);
            let rep = coord.run(steps as u64);
            println!("{}\n", rep.metrics.render());
            (coord.estimate(), format!("distributed coordinator, {steps} activations"))
        }
        "dense" => {
            let mut eng = pagerank_mp::runtime::Engine::load_default()
                .map_err(|e| format!("{e:#} (run `make artifacts`)"))?;
            let mut runner = pagerank_mp::runtime::MpChunkRunner::new(&mut eng, &g, alpha)
                .map_err(|e| e.to_string())?;
            let t = runner.chunk_len();
            let mut rng = Rng::seeded(seed);
            let chunks = steps / t;
            for _ in 0..chunks {
                let ks: Vec<usize> = (0..t).map(|_| rng.below(g.n())).collect();
                runner.run_chunk(&mut eng, &ks).map_err(|e| e.to_string())?;
            }
            (
                runner.estimate(),
                format!("dense PJRT engine ({}), {} activations", eng.platform(), chunks * t),
            )
        }
        "power" => {
            let mut pi = JacobiPowerIteration::new(&g, alpha);
            let sweeps = pi.run_to_tolerance(1e-12, 10_000);
            (pi.estimate(), format!("centralized power iteration, {sweeps} sweeps"))
        }
        e => return Err(format!("unknown engine {e:?} (sparse|coordinator|dense|power)")),
    };
    let elapsed = start.elapsed();

    let x_star = exact_pagerank(&g, alpha);
    let err = pagerank_mp::linalg::vector::dist_sq(&x, &x_star) / g.n() as f64;
    let agreement = pagerank_mp::util::stats::ranking_agreement(&x, &x_star);

    println!("engine           {label}");
    println!("elapsed          {elapsed:?}");
    println!("(1/N)|x-x*|^2    {err:.3e}");
    println!("rank agreement   {agreement:.4}");

    println!("\ntop {top} pages:");
    let ranking = pagerank_mp::util::stats::ranking(&x);
    for (rank, &page) in ranking.iter().take(top).enumerate() {
        println!("  #{:<3} page {:<6} score {:.6}", rank + 1, page, x[page]);
    }
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<(), String> {
    let cfg = fig1::Fig1Config {
        n: args.get_parse("n", 100usize).map_err(|e| e.to_string())?,
        threshold: args.get_parse("threshold", 0.5f64).map_err(|e| e.to_string())?,
        alpha: args.get_parse("alpha", 0.85f64).map_err(|e| e.to_string())?,
        rounds: args.get_parse("rounds", 100usize).map_err(|e| e.to_string())?,
        steps: args.get_parse("steps", 60_000usize).map_err(|e| e.to_string())?,
        stride: args.get_parse("stride", 500usize).map_err(|e| e.to_string())?,
        seed: args.get_parse("seed", 2017u64).map_err(|e| e.to_string())?,
        threads: args
            .get_parse(
                "threads",
                std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4),
            )
            .map_err(|e| e.to_string())?,
    };
    eprintln!("running Fig. 1: N={} rounds={} steps={} …", cfg.n, cfg.rounds, cfg.steps);
    let res = fig1::run(&cfg);
    println!("{}", res.render());
    for (claim, ok) in res.claims() {
        println!("[{}] {claim}", if ok { "PASS" } else { "FAIL" });
    }
    let out = args.get_str("out", "reports/fig1.csv");
    report::write_file(std::path::Path::new(&out), &res.to_csv()).map_err(|e| e.to_string())?;
    println!("\nwrote {out}");
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<(), String> {
    let cfg = fig2::Fig2Config {
        n: args.get_parse("n", 100usize).map_err(|e| e.to_string())?,
        threshold: args.get_parse("threshold", 0.5f64).map_err(|e| e.to_string())?,
        rounds: args.get_parse("rounds", 1000usize).map_err(|e| e.to_string())?,
        steps: args.get_parse("steps", 20_000usize).map_err(|e| e.to_string())?,
        stride: args.get_parse("stride", 200usize).map_err(|e| e.to_string())?,
        seed: args.get_parse("seed", 2017u64).map_err(|e| e.to_string())?,
        threads: args
            .get_parse(
                "threads",
                std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4),
            )
            .map_err(|e| e.to_string())?,
    };
    eprintln!("running Fig. 2: N={} rounds={} steps={} …", cfg.n, cfg.rounds, cfg.steps);
    let res = fig2::run(&cfg);
    println!("{}", res.render());
    for (claim, ok) in res.claims() {
        println!("[{}] {claim}", if ok { "PASS" } else { "FAIL" });
    }
    let out = args.get_str("out", "reports/fig2.csv");
    report::write_file(std::path::Path::new(&out), &res.to_csv()).map_err(|e| e.to_string())?;
    println!("\nwrote {out}");
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<(), String> {
    let n = args.get_parse("n", 100usize).map_err(|e| e.to_string())?;
    let seed = args.get_parse("seed", 2017u64).map_err(|e| e.to_string())?;

    println!("== ABL-RATE: measured vs predicted contraction ==");
    let rows = ablation::rate_study(n, 0.85, 20, 40_000, seed);
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.family.clone(),
                format!("{:.6}", r.predicted_bound),
                format!("{:.6}", r.measured_rate),
                format!("{:.2}x", r.tightness),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["family", "bound 1-σ²/N", "measured", "tightness"], &table_rows)
    );

    println!("== ABL-SAMPLER: activation strategies (§IV-3) ==");
    let rows = ablation::sampler_study(n, 0.85, 20_000, seed);
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.sampler.clone(),
                format!("{:.3e}", r.final_error),
                r.deferred.to_string(),
            ]
        })
        .collect();
    println!("{}", report::table(&["sampler", "(1/N)|x-x*|²", "deferred"], &table_rows));

    println!("== ABL-PARALLEL: conflict-free batches (§IV-1) ==");
    let rows = ablation::parallel_study(500, 0.85, &[1, 4, 16, 64], &[0.004, 0.02, 0.1], 500, seed);
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.3}", r.density),
                r.requested_batch.to_string(),
                format!("{:.2}", r.effective_batch),
                format!("{:.3e}", r.final_error),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["density", "batch req.", "batch eff.", "error"], &table_rows)
    );

    println!("== ABL-GREEDY: randomized vs best-atom (§II-B) ==");
    let rows = ablation::greedy_study(n, 0.85, 30_000, seed);
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.algo.clone(),
                format!("{:.3e}", r.final_error),
                r.total_reads.to_string(),
            ]
        })
        .collect();
    println!("{}", report::table(&["algorithm", "error", "total reads"], &table_rows));
    Ok(())
}

fn cmd_size(args: &Args) -> Result<(), String> {
    let g = load_graph(args)?;
    let steps = args.get_parse("steps", 20_000usize).map_err(|e| e.to_string())?;
    let seed = args.get_parse("seed", 2017u64).map_err(|e| e.to_string())?;
    let mut est = SizeEstimator::new(&g).map_err(|e| e.to_string())?;
    let mut rng = Rng::seeded(seed);
    for t in 0..steps {
        est.step(&mut rng);
        if (t + 1) % (steps / 10).max(1) == 0 {
            println!("t={:<8} ‖s-1/N‖² = {:.3e}", t + 1, est.error_sq());
        }
    }
    println!("\nper-page estimates of N (true N = {}):", g.n());
    for i in (0..g.n()).step_by((g.n() / 8).max(1)) {
        match est.estimate_at(i) {
            Some(nd) => println!("  page {i:<6} N̂ = {nd:.3}"),
            None => println!("  page {i:<6} (not yet positive)"),
        }
    }
    Ok(())
}

fn cmd_gen_corpus(args: &Args) -> Result<(), String> {
    let n = args.get_parse("n", 1_000_000usize).map_err(|e| e.to_string())?;
    let seed = args.get_parse("seed", 2017u64).map_err(|e| e.to_string())?;
    let out = args.get_str("out", "corpus/webgraph.txt");
    if n < 2 {
        return Err("gen-corpus needs --n >= 2".into());
    }
    let path = std::path::Path::new(&out);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
        }
    }
    let t0 = std::time::Instant::now();
    let f = std::fs::File::create(path).map_err(|e| format!("creating {out}: {e}"))?;
    // The generator streams rows straight to the writer: peak memory is
    // one row, independent of n.
    generators::write_webgraph_corpus(n, seed, std::io::BufWriter::new(f))
        .map_err(|e| format!("writing {out}: {e}"))?;
    println!("wrote {out}: {n} pages (seed {seed}) in {:?}", t0.elapsed());
    println!("load it with: --graph-file {out} --dangling selfloop  (or file:{out}:selfloop in a scenario)");
    Ok(())
}

fn cmd_graph_info(args: &Args) -> Result<(), String> {
    let g = load_graph(args)?;
    let stats = pagerank_mp::graph::stats::DegreeStats::compute(&g);
    println!("{}", stats.render());
    println!(
        "strongly connected: {}",
        pagerank_mp::graph::scc::is_strongly_connected(&g)
    );
    println!("SCC count: {}", pagerank_mp::graph::scc::scc_count(&g));
    println!(
        "predicted MP rate 1-σ²(B̂)/N: {:.6}",
        pagerank_mp::linalg::spectral::mp_contraction_rate(&g, 0.85)
    );
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> Result<(), String> {
    let dir = pagerank_mp::runtime::artifact_dir();
    let manifest = pagerank_mp::runtime::Manifest::load(&dir)
        .map_err(|e| format!("{e} — run `make artifacts`"))?;
    println!("artifact dir: {}", dir.display());
    println!("kernel block: {}", manifest.block);
    for a in &manifest.artifacts {
        println!(
            "  {:<16} P={:<5} T={:<5} {}",
            a.kind.name(),
            a.padded_size,
            a.chunk.map(|c| c.to_string()).unwrap_or_else(|| "-".into()),
            a.file
        );
    }
    Ok(())
}

const USAGE: &str = "\
pagerank-mp — fully distributed PageRank via randomized Matching Pursuit

USAGE: pagerank-mp <command> [options]

COMMANDS:
  run-scenario run a declarative experiment from JSON
              <scenario.json> [--bench-out BENCH_scenario.json --csv out.csv --threads T]
              (PageRank races: examples/fig1_scenario.json; size-estimation races:
               examples/fig2_scenario.json; run names via `list-solvers`)
  sweep       expand one scenario over a grid and merge the reports
              <sweep.json> [--bench-out BENCH_sweep.json --threads T]
              (axes: graph, n, alpha, steps, stride, rounds, seed, shards, batch,
               packer, sampling, latency, gossip; see examples/sweep_small.json)
  list-solvers print the engine's solver and estimator registries
  rank        compute PageRank        --graph paper|ba|ws|.. --n 100 --engine sparse|coordinator|dense|power
              [--alpha 0.85 --steps 100000 --seed S --top 10 --latency zero|const:L --mode sequential|async --sampler uniform|clocks|weighted]
  fig1        reproduce Figure 1      [--n 100 --rounds 100 --steps 60000 --stride 500 --out reports/fig1.csv]
  fig2        reproduce Figure 2      [--n 100 --rounds 1000 --steps 20000 --stride 200 --out reports/fig2.csv]
  ablation    DESIGN.md §4 studies    [--n 100 --seed S]
  size        Algorithm 2 demo        [--graph paper --n 100 --steps 20000]
  graph-info  graph statistics        [--graph paper --n 100 | --graph-file edges.txt]
  gen-corpus  write a deterministic synthetic webgraph corpus (streaming; SNAP-style text)
              [--n 1000000 --seed 2017 --out corpus/webgraph.txt]
  artifacts   inspect AOT manifest

GRAPH INPUT (rank, size, graph-info):
  --graph-file edges.txt      SNAP-style edge list (streaming two-pass loader)
  --dangling error|selfloop|linkall   sink repair policy (default linkall;
                              use selfloop for corpus-scale files)
  --remap-ids                 compact non-contiguous ids (SNAP dumps)
  --cache                     keep/reuse a validated .csrbin sidecar
";

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_deref() {
        Some("run-scenario") => cmd_run_scenario(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("list-solvers") => cmd_list_solvers(&args),
        Some("rank") => cmd_rank(&args),
        Some("fig1") => cmd_fig1(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("size") => cmd_size(&args),
        Some("graph-info") => cmd_graph_info(&args),
        Some("gen-corpus") => cmd_gen_corpus(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(c) => Err(format!("unknown command {c:?}\n\n{USAGE}")),
    };
    let unknown = args.unknown_keys();
    if !unknown.is_empty() {
        eprintln!("warning: unused options: {unknown:?}");
    }
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
