//! Linear-algebra substrate (f64, dependency-free).
//!
//! * [`dense`] — column-major dense matrices, the hyperlink matrix `A`
//!   and `B = I - αA` materializations used by reference computations.
//! * [`sparse`] — the sparse column view of `B` that the matrix-form MP
//!   solver iterates on (`O(N_k)` per activation, the paper's cost model).
//! * [`vector`] — dot/axpy/norm primitives shared by every algorithm.
//! * [`select`] — the indexed selection engine: O(log N) argmax
//!   ([`select::MaxScoreTree`]) and weighted sampling
//!   ([`select::WeightTree`]) shared by greedy-MP, the residual-weighted
//!   matrix-form solver and the sharded runtime's sampling policies.
//! * [`solve`] — LU decomposition with partial pivoting: produces the
//!   exact scaled-PageRank reference `x*` of Proposition 1.
//! * [`spectral`] — symmetric (Jacobi-rotation) eigensolver to obtain
//!   `σ(B̂)` and `σ₂(Ĉ)`, the quantities controlling the paper's
//!   convergence rates (Prop. 2 and the Appendix bound).

pub mod dense;
pub mod select;
pub mod solve;
pub mod sparse;
pub mod spectral;
pub mod vector;

pub use dense::DenseMatrix;
pub use select::{MaxScoreTree, WeightTree};
pub use sparse::BColumns;
