//! Symmetric eigensolver (cyclic Jacobi rotations) and the paper's rate
//! constants.
//!
//! Proposition 2 bounds the contraction per step by `1 - σ²(B̂)/N` where
//! `B̂` is the column-normalized `B`; the Appendix bound for Algorithm 2
//! uses `σ₂(Ĉ)`, the second-smallest eigenvalue of `Ĉ = Σ_k C_k`
//! (sum of row projectors of `C = (I-A)ᵀ`). Both reduce to eigenvalues of
//! small symmetric PSD matrices, which the Jacobi method computes to
//! machine precision — robust and dependency-free.

use super::dense::DenseMatrix;
use crate::graph::Graph;

/// All eigenvalues of a symmetric matrix, ascending. Cyclic Jacobi;
/// converges quadratically, O(n³) per sweep (reference scales only).
pub fn symmetric_eigenvalues(a: &DenseMatrix) -> Vec<f64> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut m = a.clone();
    // Verify symmetry up to a tolerance, then symmetrize exactly.
    for i in 0..n {
        for j in (i + 1)..n {
            let d = (m.get(i, j) - m.get(j, i)).abs();
            assert!(d < 1e-8, "matrix not symmetric at ({i},{j}): diff {d}");
            let avg = 0.5 * (m.get(i, j) + m.get(j, i));
            m.set(i, j, avg);
            m.set(j, i, avg);
        }
    }
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                // Stable tangent of the rotation angle.
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply the rotation G(p,q) on both sides.
                for k in 0..n {
                    let akp = m.get(k, p);
                    let akq = m.get(k, q);
                    m.set(k, p, c * akp - s * akq);
                    m.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = m.get(p, k);
                    let aqk = m.get(q, k);
                    m.set(p, k, c * apk - s * aqk);
                    m.set(q, k, s * apk + c * aqk);
                }
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    eig.sort_by(|a, b| a.partial_cmp(b).expect("NaN eigenvalue"));
    eig
}

/// Singular values of a (square) matrix, ascending — via eigenvalues of
/// `MᵀM`.
pub fn singular_values(m: &DenseMatrix) -> Vec<f64> {
    let mtm = m.transpose().matmul(m);
    symmetric_eigenvalues(&mtm)
        .into_iter()
        .map(|l| l.max(0.0).sqrt())
        .collect()
}

/// `σ(B̂)` — smallest singular value of the column-normalized
/// `B = I - αA`. Controls the paper's Algorithm 1 rate.
pub fn sigma_min_bhat(g: &Graph, alpha: f64) -> f64 {
    let bhat = DenseMatrix::b_matrix(g, alpha).column_normalized();
    singular_values(&bhat)[0]
}

/// The paper's predicted per-step contraction `ρ = 1 - σ²(B̂)/N` for
/// `E‖r_t‖²` (Proposition 2 / eq. 9).
pub fn mp_contraction_rate(g: &Graph, alpha: f64) -> f64 {
    let s = sigma_min_bhat(g, alpha);
    1.0 - s * s / g.n() as f64
}

/// `σ₂(Ĉ)` of the Appendix: second-smallest eigenvalue of
/// `Ĉ = Σ_k C(k,:)ᵀC(k,:)/‖C(k,:)‖²` with `C = (I-A)ᵀ`. The smallest is 0
/// (nullspace spanned by the stationary vector s).
pub fn sigma2_chat(g: &Graph) -> f64 {
    let n = g.n();
    let a = DenseMatrix::hyperlink(g);
    // C = (I - A)^T: row k of C is column k of (I - A).
    let mut chat = DenseMatrix::zeros(n, n);
    for k in 0..n {
        // c_k = e_k - A(:,k)
        let mut c = vec![0.0; n];
        c[k] += 1.0;
        for i in 0..n {
            c[i] -= a.get(i, k);
        }
        let n2: f64 = c.iter().map(|v| v * v).sum();
        assert!(n2 > 0.0, "zero row {k} in C");
        for i in 0..n {
            for j in 0..n {
                let v = chat.get(i, j) + c[i] * c[j] / n2;
                chat.set(i, j, v);
            }
        }
    }
    let eig = symmetric_eigenvalues(&chat);
    // eig[0] ~ 0 (the nullspace); the rate constant is eig[1].
    eig[1]
}

/// Predicted per-step contraction of Algorithm 2: `1 - σ₂(Ĉ)/N`.
pub fn size_est_contraction_rate(g: &Graph) -> f64 {
    1.0 - sigma2_chat(g) / g.n() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn eigenvalues_of_diagonal() {
        let d = DenseMatrix::from_fn(3, 3, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let e = symmetric_eigenvalues(&d);
        assert!((e[0] - 1.0).abs() < 1e-12);
        assert!((e[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eigenvalues_of_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1 and 3.
        let m = DenseMatrix::from_fn(2, 2, |i, j| if i == j { 2.0 } else { 1.0 });
        let e = symmetric_eigenvalues(&m);
        assert!((e[0] - 1.0).abs() < 1e-12);
        assert!((e[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn asymmetric_panics() {
        let m = DenseMatrix::from_fn(2, 2, |i, j| (i * 2 + j) as f64);
        symmetric_eigenvalues(&m);
    }

    #[test]
    fn singular_values_of_orthogonal_scaled() {
        // diag(3, 4) rotated is still sv {3, 4}.
        let m = DenseMatrix::from_fn(2, 2, |i, j| {
            let r = [[0.6, -0.8], [0.8, 0.6]]; // rotation
            let d = [[3.0, 0.0], [0.0, 4.0]];
            r[i][0] * d[0][j] + r[i][1] * d[1][j]
        });
        let sv = singular_values(&m);
        assert!((sv[0] - 3.0).abs() < 1e-10);
        assert!((sv[1] - 4.0).abs() < 1e-10);
    }

    #[test]
    fn mp_rate_in_unit_interval() {
        let g = generators::er_threshold(50, 0.5, 21);
        let rho = mp_contraction_rate(&g, 0.85);
        assert!(rho > 0.9 && rho < 1.0, "rho={rho}");
    }

    #[test]
    fn sigma_min_positive_since_b_invertible() {
        let g = generators::ring(12);
        assert!(sigma_min_bhat(&g, 0.85) > 0.0);
    }

    #[test]
    fn chat_smallest_eigen_is_zero_and_second_positive() {
        let g = generators::er_threshold(30, 0.5, 22);
        // strongly connected -> nullspace dim 1 -> sigma2 > 0
        assert!(crate::graph::scc::is_strongly_connected(&g));
        let s2 = sigma2_chat(&g);
        assert!(s2 > 1e-6, "sigma2={s2}");
        let n = g.n();
        let a = DenseMatrix::hyperlink(&g);
        // verify the stationary direction is (near) null for Chat by
        // checking C s = 0 with s = 1/n.
        let s = vec![1.0 / n as f64; n];
        // C s = (I - A)^T s: row k = s_k - A(:,k)·s
        for k in 0..n {
            let mut v = s[k];
            for i in 0..n {
                v -= a.get(i, k) * s[i];
            }
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn size_rate_in_unit_interval() {
        let g = generators::er_threshold(30, 0.5, 23);
        let rho = size_est_contraction_rate(&g);
        assert!(rho > 0.5 && rho < 1.0, "rho={rho}");
    }
}
