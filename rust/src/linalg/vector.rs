//! Dense vector primitives (f64). The Rust hot path is sparse, but the
//! references, baselines and metrics all speak in these.

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Squared l2 norm.
#[inline]
pub fn norm2_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// l2 norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    norm2_sq(a).sqrt()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y = x` (copy).
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// Scale in place.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Sum of entries.
#[inline]
pub fn sum(x: &[f64]) -> f64 {
    x.iter().sum()
}

/// Squared distance ‖a − b‖².
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Max-abs (l∞) distance.
#[inline]
pub fn dist_inf(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norms() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        assert_eq!(norm2_sq(&a), 14.0);
        assert!((norm2(&a) - 14f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn axpy_updates() {
        let x = [1.0, -1.0];
        let mut y = [10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 8.0]);
    }

    #[test]
    fn scale_and_sum() {
        let mut x = [1.0, 2.0, 3.0];
        scale(2.0, &mut x);
        assert_eq!(sum(&x), 12.0);
    }

    #[test]
    fn distances() {
        let a = [0.0, 3.0];
        let b = [4.0, 0.0];
        assert_eq!(dist_sq(&a, &b), 25.0);
        assert_eq!(dist_inf(&a, &b), 4.0);
    }
}
