//! Sparse column view of `B = I - αA`.
//!
//! The paper's Algorithm 1 only ever touches `B` column-wise:
//!
//! * `B(:,k)ᵀ r = r_k - (α/N_k) Σ_{j ∈ out(k)} r_j`   (numerator, §II-D)
//! * `‖B(:,k)‖² = 1 - 2αA_kk + α²/N_k`                 (denominator, §II-D)
//! * the residual update adds `-coef · B(:,k)`, whose support is
//!   `{k} ∪ out(k)`.
//!
//! [`BColumns`] precomputes the per-column constants (Remark 3) and
//! exposes exactly those three operations at `O(N_k)` cost with zero
//! allocation, which is what the matrix-form solver and the page agents
//! share.
//!
//! ## Dangling pages
//!
//! The paper assumes no dangling (zero out-degree) pages; real crawls
//! have them, and an unguarded `α/N_k` with `N_k = 0` poisons every
//! residual with NaN/inf. This module is the **one shared guard**: a
//! dangling page is treated as carrying an implicit self-loop
//! (`N_k = 1`, `out(k) = {k}`, `A_kk = 1`) — the same local repair as
//! [`crate::graph::DanglingPolicy::SelfLoop`], applied on the fly so
//! every solver built on these column ops (matrix-form MP, greedy,
//! parallel batches, the sharded runtime) agrees on one operator without
//! rebuilding the graph.

use crate::graph::Graph;

/// Precomputed column geometry of `B = I - αA` over a graph.
#[derive(Debug, Clone)]
pub struct BColumns {
    alpha: f64,
    /// ‖B(:,k)‖² per column (paper Remark 3).
    norms_sq: Vec<f64>,
    /// 1/N_k per column.
    inv_out_deg: Vec<f64>,
    /// whether k links to itself (A_kk = 1/N_k).
    self_loop: Vec<bool>,
    /// whether k is dangling and carries the implicit self-loop repair
    /// (its column support is {k} although `graph.out(k)` is empty).
    dangling: Vec<bool>,
}

impl BColumns {
    pub fn new(g: &Graph, alpha: f64) -> BColumns {
        assert!((0.0..1.0).contains(&alpha) && alpha > 0.0, "alpha must be in (0,1)");
        let n = g.n();
        let mut norms_sq = Vec::with_capacity(n);
        let mut inv_out_deg = Vec::with_capacity(n);
        let mut self_loop = Vec::with_capacity(n);
        let mut dangling = Vec::with_capacity(n);
        for k in 0..n {
            let deg = g.out_degree(k);
            // Dangling guard: repair with an implicit self-loop
            // (N_k = 1, A_kk = 1), so the column is B(:,k) = (1-α)e_k.
            let (nk, akk) = if deg == 0 {
                (1.0, 1.0)
            } else {
                let nk = deg as f64;
                (nk, if g.has_self_loop(k) { 1.0 / nk } else { 0.0 })
            };
            // ‖B(:,k)‖² = 1 - 2 α A_kk + α²/N_k  (§II-D)
            norms_sq.push(1.0 - 2.0 * alpha * akk + alpha * alpha / nk);
            inv_out_deg.push(1.0 / nk);
            self_loop.push(akk > 0.0);
            dangling.push(deg == 0);
        }
        BColumns {
            alpha,
            norms_sq,
            inv_out_deg,
            self_loop,
            dangling,
        }
    }

    #[inline]
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.norms_sq.len()
    }

    /// `‖B(:,k)‖²` — O(1).
    #[inline]
    pub fn norm_sq(&self, k: usize) -> f64 {
        self.norms_sq[k]
    }

    #[inline]
    pub fn has_self_loop(&self, k: usize) -> bool {
        self.self_loop[k]
    }

    /// `1/N_k` — O(1). `1.0` for dangling pages (implicit self-loop).
    #[inline]
    pub fn inv_out_degree(&self, k: usize) -> f64 {
        self.inv_out_deg[k]
    }

    /// Whether page `k` had no out-links and carries the implicit
    /// self-loop repair (see the module docs).
    #[inline]
    pub fn is_dangling(&self, k: usize) -> bool {
        self.dangling[k]
    }

    /// `B(:,k)ᵀ r` given the residual vector — O(N_k): one read per
    /// out-neighbour, exactly the paper's communication count.
    #[inline]
    pub fn col_dot(&self, g: &Graph, k: usize, r: &[f64]) -> f64 {
        let mut s = 0.0;
        for &j in g.out(k) {
            s += r[j as usize];
        }
        if self.dangling[k] {
            // implicit self-loop: the only "out-neighbour" is k itself
            s += r[k];
        }
        r[k] - self.alpha * self.inv_out_deg[k] * s
    }

    /// The MP projection coefficient `B(:,k)ᵀ r / ‖B(:,k)‖²`.
    #[inline]
    pub fn coefficient(&self, g: &Graph, k: usize, r: &[f64]) -> f64 {
        self.col_dot(g, k, r) / self.norms_sq[k]
    }

    /// `r -= coef * B(:,k)` — O(N_k): one write per out-neighbour plus the
    /// diagonal entry (§II-D residual update).
    #[inline]
    pub fn sub_scaled_col(&self, g: &Graph, k: usize, coef: f64, r: &mut [f64]) {
        // Off-diagonal support: out-neighbours get -α/N_k entries.
        let w = coef * self.alpha * self.inv_out_deg[k];
        for &j in g.out(k) {
            r[j as usize] += w;
        }
        if self.dangling[k] {
            // implicit self-loop: k is its own (only) out-neighbour
            r[k] += w;
        }
        // Diagonal entry of B(:,k) is 1 - αA_kk; the self-loop case already
        // received its +w above, so subtracting coef·1 completes
        // coef·(1 - α/N_k) for it and coef·1 for the non-loop case.
        r[k] -= coef;
    }

    /// Materialize column k densely (tests / cross-checks only).
    pub fn dense_col(&self, g: &Graph, k: usize) -> Vec<f64> {
        let mut col = vec![0.0; self.n()];
        col[k] = 1.0;
        let w = self.alpha * self.inv_out_deg[k];
        for &j in g.out(k) {
            col[j as usize] -= w;
        }
        if self.dangling[k] {
            col[k] -= w;
        }
        col
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::vector;
    use crate::util::rng::Rng;

    fn check_against_dense(g: &Graph, alpha: f64, seed: u64) {
        let cols = BColumns::new(g, alpha);
        let b = DenseMatrix::b_matrix(g, alpha);
        let mut rng = Rng::seeded(seed);
        let r: Vec<f64> = (0..g.n()).map(|_| rng.normal()).collect();
        for k in 0..g.n() {
            // norms
            let want_n2 = vector::norm2_sq(b.col(k));
            assert!(
                (cols.norm_sq(k) - want_n2).abs() < 1e-12,
                "norm_sq mismatch at {k}"
            );
            // dot
            let want_dot = vector::dot(b.col(k), &r);
            assert!(
                (cols.col_dot(g, k, &r) - want_dot).abs() < 1e-10,
                "col_dot mismatch at {k}"
            );
            // dense col
            let got = cols.dense_col(g, k);
            for i in 0..g.n() {
                assert!((got[i] - b.get(i, k)).abs() < 1e-14);
            }
            // sub_scaled_col
            let coef = 0.37;
            let mut r2 = r.clone();
            cols.sub_scaled_col(g, k, coef, &mut r2);
            for i in 0..g.n() {
                let want = r[i] - coef * b.get(i, k);
                assert!((r2[i] - want).abs() < 1e-12, "residual mismatch at ({k},{i})");
            }
        }
    }

    #[test]
    fn matches_dense_on_er() {
        let g = generators::er_threshold(40, 0.5, 2);
        check_against_dense(&g, 0.85, 7);
    }

    #[test]
    fn matches_dense_with_self_loops() {
        // SelfLoop-repaired sparse graph guarantees some A_kk > 0.
        let mut b = crate::graph::GraphBuilder::new(6)
            .dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 1).add_edge(1, 2).add_edge(2, 0).add_edge(3, 3).add_edge(4, 4);
        let g = b.build().expect("builds");
        assert!(g.has_self_loop(3));
        check_against_dense(&g, 0.85, 8);
    }

    #[test]
    fn matches_dense_on_star_and_ring() {
        check_against_dense(&generators::star(9), 0.85, 9);
        check_against_dense(&generators::ring(9), 0.6, 10);
    }

    #[test]
    fn norm_formula_closed_form() {
        let g = generators::ring(5); // N_k = 1, no self loops
        let cols = BColumns::new(&g, 0.85);
        for k in 0..5 {
            assert!((cols.norm_sq(k) - (1.0 + 0.85 * 0.85)).abs() < 1e-15);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_alpha_one() {
        let g = generators::ring(3);
        BColumns::new(&g, 1.0);
    }

    #[test]
    fn dangling_column_is_implicit_self_loop() {
        // Page 1 has no out-links: its column must equal the SelfLoop
        // repair's column, (1-α)e_1, and match the dense B of the
        // explicitly repaired graph everywhere.
        let alpha = 0.85;
        let g = crate::graph::Graph::from_sorted_edges(2, &[(0, 1)]);
        let cols = BColumns::new(&g, alpha);
        assert!(cols.is_dangling(1));
        assert!(!cols.is_dangling(0));
        assert!((cols.norm_sq(1) - (1.0 - alpha) * (1.0 - alpha)).abs() < 1e-15);
        assert_eq!(cols.inv_out_degree(1), 1.0);

        let mut b = crate::graph::GraphBuilder::new(2)
            .dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 1);
        let repaired = b.build().expect("builds");
        let rcols = BColumns::new(&repaired, alpha);
        let r = [0.3, -1.7];
        for k in 0..2 {
            assert!((cols.norm_sq(k) - rcols.norm_sq(k)).abs() < 1e-15);
            assert!(
                (cols.col_dot(&g, k, &r) - rcols.col_dot(&repaired, k, &r)).abs() < 1e-15,
                "col_dot mismatch at {k}"
            );
            let (mut a, mut bq) = (r.to_vec(), r.to_vec());
            cols.sub_scaled_col(&g, k, 0.41, &mut a);
            rcols.sub_scaled_col(&repaired, k, 0.41, &mut bq);
            assert_eq!(a, bq, "residual update mismatch at {k}");
            assert_eq!(cols.dense_col(&g, k), rcols.dense_col(&repaired, k));
        }
    }

    #[test]
    fn dangling_guard_keeps_mp_finite_and_convergent() {
        // Regression for the α/N_k division by zero: a graph with a sink
        // page must run Algorithm 1 to convergence with finite errors.
        let g = crate::graph::Graph::from_sorted_edges(
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 0), (2, 3)], // page 3 is a sink
        );
        assert_eq!(g.dangling(), vec![3]);
        let x_star = crate::linalg::solve::exact_pagerank(&g, 0.85);
        assert!(x_star.iter().all(|v| v.is_finite()));
        let mut mp = crate::algo::mp::MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(77);
        for _ in 0..20_000 {
            crate::algo::common::PageRankSolver::step(&mut mp, &mut rng);
        }
        let est = crate::algo::common::PageRankSolver::estimate(&mp);
        assert!(est.iter().all(|v| v.is_finite()), "estimate poisoned: {est:?}");
        assert!(
            vector::dist_inf(&est, &x_star) < 1e-8,
            "did not converge: {est:?} vs {x_star:?}"
        );
    }
}
