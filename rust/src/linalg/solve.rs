//! Dense LU solve with partial pivoting.
//!
//! Produces the exact scaled-PageRank reference of Proposition 1,
//! `x* = (1-α)(I-αA)⁻¹𝟙`, against which every algorithm's trajectory
//! error `(1/N)‖x_t - x*‖²` (Fig. 1's y-axis) is measured.

use super::dense::DenseMatrix;
use crate::graph::Graph;

/// LU factorization (PA = LU) of a square matrix.
#[derive(Debug, Clone)]
pub struct Lu {
    n: usize,
    /// Combined L (unit lower, below diagonal) and U (upper incl. diagonal).
    lu: DenseMatrix,
    /// Row permutation: row i of PA is row perm[i] of A.
    perm: Vec<usize>,
}

/// Error for singular systems.
#[derive(Debug, PartialEq)]
pub struct SingularMatrix {
    pub pivot: usize,
}

impl std::fmt::Display for SingularMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix is numerically singular at pivot {}", self.pivot)
    }
}

impl std::error::Error for SingularMatrix {}

impl Lu {
    /// Factorize. O(n³); reference scales only.
    pub fn factor(a: &DenseMatrix) -> Result<Lu, SingularMatrix> {
        assert_eq!(a.rows(), a.cols(), "LU of non-square matrix");
        let n = a.rows();
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        for col in 0..n {
            // Pivot: largest |entry| on/below the diagonal.
            let mut p = col;
            let mut best = lu.get(col, col).abs();
            for r in (col + 1)..n {
                let v = lu.get(r, col).abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-300 {
                return Err(SingularMatrix { pivot: col });
            }
            if p != col {
                for j in 0..n {
                    let tmp = lu.get(col, j);
                    lu.set(col, j, lu.get(p, j));
                    lu.set(p, j, tmp);
                }
                perm.swap(col, p);
            }
            let piv = lu.get(col, col);
            for r in (col + 1)..n {
                let m = lu.get(r, col) / piv;
                lu.set(r, col, m);
                if m != 0.0 {
                    for j in (col + 1)..n {
                        let v = lu.get(r, j) - m * lu.get(col, j);
                        lu.set(r, j, v);
                    }
                }
            }
        }
        Ok(Lu { n, lu, perm })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        // Forward substitution on P b.
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let mut s = b[self.perm[i]];
            for j in 0..i {
                s -= self.lu.get(i, j) * y[j];
            }
            y[i] = s;
        }
        // Back substitution.
        let mut x = vec![0.0; self.n];
        for i in (0..self.n).rev() {
            let mut s = y[i];
            for j in (i + 1)..self.n {
                s -= self.lu.get(i, j) * x[j];
            }
            x[i] = s / self.lu.get(i, i);
        }
        x
    }
}

/// The exact scaled PageRank vector `x* = (1-α)(I-αA)⁻¹𝟙` (Prop. 1).
/// Dangling pages take the implicit self-loop repair (see
/// [`DenseMatrix::hyperlink`]); `I-αA` is always invertible for
/// α ∈ (0,1) by Gershgorin (paper's Prop. 1 proof).
pub fn exact_pagerank(g: &Graph, alpha: f64) -> Vec<f64> {
    let b = DenseMatrix::b_matrix(g, alpha);
    let lu = Lu::factor(&b).expect("I - alpha A is provably invertible");
    let rhs = vec![1.0 - alpha; g.n()];
    lu.solve(&rhs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::vector;

    #[test]
    fn solve_small_system() {
        // A = [[2, 1], [1, 3]], b = [3, 5] -> x = [4/5, 7/5]
        let a = DenseMatrix::from_fn(2, 2, |i, j| [[2.0, 1.0], [1.0, 3.0]][i][j]);
        let lu = Lu::factor(&a).expect("nonsingular");
        let x = lu.solve(&[3.0, 5.0]);
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn solve_requires_pivoting() {
        // Zero leading pivot forces a row swap.
        let a = DenseMatrix::from_fn(2, 2, |i, j| [[0.0, 1.0], [1.0, 0.0]][i][j]);
        let lu = Lu::factor(&a).expect("nonsingular with pivoting");
        let x = lu.solve(&[2.0, 3.0]);
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_detected() {
        let a = DenseMatrix::from_fn(2, 2, |i, _| if i == 0 { 1.0 } else { 2.0 });
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn residual_is_tiny_on_random_system() {
        let n = 50;
        let rng = std::cell::RefCell::new(crate::util::rng::Rng::seeded(3));
        let a = DenseMatrix::from_fn(n, n, |_, _| rng.borrow_mut().normal());
        let mut rng2 = crate::util::rng::Rng::seeded(4);
        let b: Vec<f64> = (0..n).map(|_| rng2.normal()).collect();
        let lu = Lu::factor(&a).expect("random gaussian is nonsingular whp");
        let x = lu.solve(&b);
        let ax = a.matvec(&x);
        assert!(vector::dist_inf(&ax, &b) < 1e-9);
    }

    #[test]
    fn exact_pagerank_satisfies_definition() {
        let g = generators::er_threshold(60, 0.5, 12);
        let alpha = 0.85;
        let x = exact_pagerank(&g, alpha);
        // (1b): entries sum to N and are nonnegative.
        assert!((vector::sum(&x) - g.n() as f64).abs() < 1e-8);
        assert!(x.iter().all(|&v| v > 0.0));
        // (1a): B x* = (1-α) 1.
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&x);
        for v in bx {
            assert!((v - (1.0 - alpha)).abs() < 1e-10);
        }
    }

    #[test]
    fn exact_pagerank_is_google_eigenvector() {
        let g = generators::er_threshold(40, 0.5, 13);
        let x = exact_pagerank(&g, 0.85);
        let m = DenseMatrix::google_matrix(&g, 0.85);
        let mx = m.matvec(&x);
        assert!(vector::dist_inf(&mx, &x) < 1e-10, "M x* != x*");
    }

    #[test]
    fn ring_pagerank_uniform() {
        // Perfect symmetry -> scaled PageRank = 1 everywhere.
        let x = exact_pagerank(&generators::ring(8), 0.85);
        for v in x {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn star_hub_dominates() {
        let x = exact_pagerank(&generators::star(10), 0.85);
        let hub = x[0];
        for leaf in &x[1..] {
            assert!(hub > *leaf);
        }
    }
}
