//! Indexed selection: O(log N) argmax and weighted sampling over a
//! score vector that changes in few coordinates per step.
//!
//! Per-step selection over N pages shows up three times in this
//! repository, and all three are the same access pattern — a score
//! vector updated only on `{k} ∪ in(out(k))` after an activation at `k`:
//!
//! * the greedy-MP ablation's best-atom rule (Mallat–Zhang §II-B) needs
//!   `argmax_k |B(:,k)ᵀr|/‖B(:,k)‖` every step — [`MaxScoreTree`];
//! * the §IV-3 residual-weighted matrix-form solver samples
//!   `k ∝ max(r_k², floor)` — [`WeightTree`];
//! * the sharded runtime's per-shard residual samplers do the same over
//!   each worker's owned pages — [`WeightTree`] again.
//!
//! A linear scan makes each of these O(N) per step; both trees make
//! them O(log N) per update/query, which is what lets the greedy
//! ablation and the residual policies run at 10⁵⁺ pages.
//!
//! ## Floating-point discipline
//!
//! [`MaxScoreTree`] stores scores exactly and recomputes internal nodes
//! as `max` of their children — `max` introduces no rounding, so the
//! tree can never drift from the leaves and needs no rebuild.
//!
//! [`WeightTree`] accumulates *sums*, and its point update adds a
//! `new - old` delta into O(log N) nodes: after many updates the
//! internal partial sums drift away from the exact weights by
//! accumulated rounding, which can push `total()` slightly negative and
//! break sampling (the PR-5 regression). The tree therefore counts
//! updates and rebuilds its internal nodes *exactly* from the stored
//! weights every [`WeightTree::rebuild_every`] updates, bounding the
//! drift to what O(n) fresh additions can produce.

use crate::util::rng::Rng;

/// Default weight floor for residual-weighted sampling: weighting pages
/// by `max(r_k², floor)` with `floor > 0` keeps every page's activation
/// probability positive, so the residual still contracts in expectation
/// (every coordinate is visited infinitely often — see
/// docs/ENGINE.md). Shared by `mp:residual`, the sharded `residual`
/// sampling policy and the simulated coordinator's weighted sampler.
pub const DEFAULT_WEIGHT_FLOOR: f64 = 1e-12;

/// Segment tree over scores: O(log N) point update, O(log N) argmax
/// (leftmost index on ties, matching a first-wins linear scan).
#[derive(Debug, Clone)]
pub struct MaxScoreTree {
    /// Number of leaves (next power of two ≥ `n`).
    size: usize,
    /// Number of live scores.
    n: usize,
    /// `2*size` slots; root at 1, leaf `i` at `size + i`, padding leaves
    /// hold `-∞` so they never win the argmax.
    tree: Vec<f64>,
}

impl MaxScoreTree {
    pub fn new(scores: &[f64]) -> MaxScoreTree {
        let n = scores.len();
        assert!(n > 0, "empty score set");
        let size = n.next_power_of_two();
        let mut tree = vec![f64::NEG_INFINITY; 2 * size];
        tree[size..size + n].copy_from_slice(scores);
        for i in (1..size).rev() {
            tree[i] = tree[2 * i].max(tree[2 * i + 1]);
        }
        MaxScoreTree { size, n, tree }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current score of index `i` — O(1).
    #[inline]
    pub fn score(&self, i: usize) -> f64 {
        self.tree[self.size + i]
    }

    /// The maximum score — O(1).
    #[inline]
    pub fn max_score(&self) -> f64 {
        self.tree[1]
    }

    /// Set the score of index `i` — O(log N), early-exits once an
    /// ancestor's max is unchanged.
    pub fn update(&mut self, i: usize, score: f64) {
        assert!(i < self.n, "index {i} out of range {}", self.n);
        debug_assert!(!score.is_nan(), "NaN score would poison the argmax");
        let mut node = self.size + i;
        self.tree[node] = score;
        node >>= 1;
        while node >= 1 {
            let m = self.tree[2 * node].max(self.tree[2 * node + 1]);
            if self.tree[node] == m {
                break; // invariant holds here, hence on every ancestor
            }
            self.tree[node] = m;
            node >>= 1;
        }
    }

    /// Index of the maximum score — O(log N); ties resolve to the
    /// lowest index (the same winner a first-wins linear scan picks).
    pub fn argmax(&self) -> usize {
        let mut node = 1usize;
        while node < self.size {
            node = if self.tree[2 * node] >= self.tree[2 * node + 1] {
                2 * node
            } else {
                2 * node + 1
            };
        }
        node - self.size
    }
}

/// Fenwick (binary indexed) tree over non-negative weights, supporting
/// point updates and sampling proportional to weight in O(log N), with
/// a counted exact rebuild that cancels floating-point drift (see the
/// module docs).
#[derive(Debug, Clone)]
pub struct WeightTree {
    tree: Vec<f64>,
    weights: Vec<f64>,
    /// Point updates since the last exact rebuild.
    updates: u64,
    /// Rebuild period; scales with n so the amortized rebuild cost per
    /// update stays O(1).
    rebuild_every: u64,
}

impl WeightTree {
    pub fn new(weights: &[f64]) -> WeightTree {
        let n = weights.len();
        for (i, &w) in weights.iter().enumerate() {
            assert!(w >= 0.0, "negative weight {w} at {i}");
        }
        let mut t = WeightTree {
            tree: vec![0.0; n + 1],
            weights: weights.to_vec(),
            updates: 0,
            rebuild_every: (4 * n as u64).max(4096),
        };
        t.rebuild();
        t
    }

    /// Override the rebuild period (tests exercise drift with a tiny
    /// period; production code keeps the default).
    pub fn with_rebuild_every(mut self, every: u64) -> WeightTree {
        assert!(every > 0);
        self.rebuild_every = every;
        self
    }

    pub fn len(&self) -> usize {
        self.weights.len()
    }

    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    pub fn total(&self) -> f64 {
        self.prefix_sum(self.weights.len())
    }

    /// Sum of weights `[0, end)`.
    pub fn prefix_sum(&self, end: usize) -> f64 {
        let mut i = end;
        let mut s = 0.0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Recompute every internal node exactly from the stored weights —
    /// O(n). Called automatically every `rebuild_every` updates, so
    /// delta-update rounding can never accumulate past one fresh
    /// summation's worth of error.
    pub fn rebuild(&mut self) {
        let n = self.weights.len();
        for (i, &w) in self.weights.iter().enumerate() {
            self.tree[i + 1] = w;
        }
        // Classic O(n) Fenwick construction: fold each node into its
        // parent range.
        for i in 1..=n {
            let j = i + (i & i.wrapping_neg());
            if j <= n {
                self.tree[j] += self.tree[i];
            }
        }
        self.updates = 0;
    }

    /// Set weight of index `i`.
    pub fn update(&mut self, i: usize, w: f64) {
        assert!(w >= 0.0, "negative weight");
        let delta = w - self.weights[i];
        self.weights[i] = w;
        let mut j = i + 1;
        while j < self.tree.len() {
            self.tree[j] += delta;
            j += j & j.wrapping_neg();
        }
        self.updates += 1;
        if self.updates >= self.rebuild_every {
            self.rebuild();
        }
    }

    pub fn weight(&self, i: usize) -> f64 {
        self.weights[i]
    }

    /// Sample an index proportional to weight.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let total = self.total();
        assert!(total > 0.0, "cannot sample from zero mass");
        let mut target = rng.uniform() * total;
        // Descend the implicit Fenwick structure.
        let mut pos = 0usize;
        let mut mask = self.tree.len().next_power_of_two() >> 1;
        while mask > 0 {
            let next = pos + mask;
            if next < self.tree.len() && self.tree[next] < target {
                target -= self.tree[next];
                pos = next;
            }
            mask >>= 1;
        }
        pos.min(self.weights.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_tree_matches_linear_scan_under_updates() {
        let mut rng = Rng::seeded(301);
        for case in 0..20u64 {
            let n = 1 + (case as usize * 7) % 70;
            let mut scores: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let mut tree = MaxScoreTree::new(&scores);
            for _ in 0..200 {
                let i = rng.below(n);
                let s = rng.uniform() * 10.0 - 5.0;
                scores[i] = s;
                tree.update(i, s);
                // linear first-wins argmax
                let mut best = 0usize;
                for (j, &v) in scores.iter().enumerate() {
                    if v > scores[best] {
                        best = j;
                    }
                }
                assert_eq!(tree.argmax(), best, "case {case}, n={n}");
                assert_eq!(tree.max_score(), scores[best]);
                assert_eq!(tree.score(i), s);
            }
        }
    }

    #[test]
    fn max_tree_ties_resolve_to_lowest_index() {
        let mut tree = MaxScoreTree::new(&[1.0, 3.0, 3.0, 2.0, 3.0]);
        assert_eq!(tree.argmax(), 1);
        tree.update(1, 0.0);
        assert_eq!(tree.argmax(), 2);
        tree.update(0, 3.0);
        assert_eq!(tree.argmax(), 0, "equal score at a lower index wins");
    }

    #[test]
    fn max_tree_single_leaf_and_padding() {
        let tree = MaxScoreTree::new(&[0.25]);
        assert_eq!(tree.argmax(), 0);
        assert_eq!(tree.max_score(), 0.25);
        // Non-power-of-two n: padding leaves (-inf) must never win.
        let mut tree = MaxScoreTree::new(&[-7.0, -9.0, -8.0]);
        assert_eq!(tree.argmax(), 0);
        tree.update(0, -10.0);
        assert_eq!(tree.argmax(), 2);
    }

    #[test]
    fn weight_tree_prefix_and_total() {
        let t = WeightTree::new(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.total(), 10.0);
        assert_eq!(t.prefix_sum(2), 3.0);
        assert_eq!(t.weight(2), 3.0);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn weight_tree_update() {
        let mut t = WeightTree::new(&[1.0, 1.0, 1.0]);
        t.update(1, 5.0);
        assert_eq!(t.total(), 7.0);
        assert_eq!(t.weight(1), 5.0);
    }

    #[test]
    fn weight_tree_sampling_proportional() {
        let t = WeightTree::new(&[1.0, 0.0, 3.0, 6.0]);
        let mut rng = Rng::seeded(151);
        let mut counts = [0usize; 4];
        let draws = 100_000;
        for _ in 0..draws {
            counts[t.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let f3 = counts[3] as f64 / draws as f64;
        assert!((f3 - 0.6).abs() < 0.01, "f3={f3}");
        let f0 = counts[0] as f64 / draws as f64;
        assert!((f0 - 0.1).abs() < 0.01, "f0={f0}");
    }

    #[test]
    fn weight_tree_rebuild_is_exact() {
        let mut rng = Rng::seeded(302);
        let weights: Vec<f64> = (0..37).map(|_| rng.uniform() * 5.0).collect();
        let mut t = WeightTree::new(&weights);
        let before: Vec<f64> = (0..=37).map(|i| t.prefix_sum(i)).collect();
        t.rebuild();
        // The exact build must agree with fresh summation of the weights.
        for (end, b) in before.iter().enumerate() {
            let exact: f64 = weights[..end].iter().sum();
            assert!((t.prefix_sum(end) - exact).abs() < 1e-12);
            assert!((b - exact).abs() < 1e-9, "pre-rebuild sums already close");
        }
    }

    #[test]
    fn weight_tree_drift_regression_under_hammering() {
        // PR-5 regression: repeated large-magnitude update/draw cycles
        // used to drift the Fenwick partial sums (total() could go
        // slightly negative and break sampling). The counted rebuild
        // bounds the drift; hammer the worst case — large cancelling
        // deltas — and check total() stays glued to the exact sum.
        let n = 8;
        let mut weights = vec![1.0; n];
        let mut t = WeightTree::new(&weights).with_rebuild_every(64);
        let mut rng = Rng::seeded(303);
        for round in 0..200_000u64 {
            let i = rng.below(n);
            let w = if round % 2 == 0 { 1e16 * rng.uniform() } else { 1e-16 * rng.uniform() };
            weights[i] = w;
            t.update(i, w);
            let _ = t.sample(&mut rng); // must never hit the zero-mass assert
            if round % 4096 == 0 {
                let exact: f64 = weights.iter().sum();
                let err = (t.total() - exact).abs();
                assert!(
                    err <= 1e-9 * exact.max(1.0),
                    "round {round}: drift {err} vs exact {exact}"
                );
            }
        }
        let exact: f64 = weights.iter().sum();
        assert!((t.total() - exact).abs() <= 1e-9 * exact.max(1.0));
        assert!(t.total() >= 0.0, "total must never go negative");
    }

    #[test]
    #[should_panic]
    fn weight_tree_rejects_negative_weights() {
        let mut t = WeightTree::new(&[1.0, 1.0]);
        t.update(0, -0.5);
    }
}
