//! Column-major dense matrices.
//!
//! Used for reference computations (exact solve, spectra, baselines'
//! expectation matrices) at the paper's experiment scales (N ≤ a few
//! thousand). The production path never materializes a dense matrix.

use crate::graph::Graph;

/// Column-major dense matrix. Column-major matches both the paper's
/// column-atom view of `B = I - αA` and the XLA f32 layout used by the
/// PJRT runtime (rust/src/runtime/pad.rs converts directly).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    /// data[j * rows + i] = entry (i, j)
    data: Vec<f64>,
}

/// Hard ceiling on dense allocations: 20k×20k f64 (3.2 GB) — the same
/// boundary [`crate::engine::scenario::DENSE_MAX_N`] enforces with a
/// `Result` at the engine layer. Past it, a dense matrix is an OOM
/// abort, not a slow reference computation; this assert turns that
/// abort into a named panic for programmatic misuse that bypasses the
/// engine.
pub const DENSE_ELEMS_MAX: usize = 400_000_000;

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> DenseMatrix {
        let elems = rows
            .checked_mul(cols)
            .expect("dense matrix dimensions overflow usize");
        assert!(
            elems <= DENSE_ELEMS_MAX,
            "refusing to allocate a dense {rows}×{cols} matrix ({elems} elements > \
             DENSE_ELEMS_MAX = {DENSE_ELEMS_MAX}): dense matrices are reference-scale \
             only — corpus-scale graphs must stay on the sparse/streaming paths"
        );
        DenseMatrix {
            rows,
            cols,
            data: vec![0.0; elems],
        }
    }

    pub fn identity(n: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a row-major closure (convenient for tests).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    /// The column-stochastic hyperlink matrix `A` of the graph:
    /// `A[i][j] = 1/N_j` iff `j` links to `i` (paper §I). Dangling pages
    /// get the implicit self-loop repair `A[j][j] = 1` — the same
    /// convention as [`crate::linalg::sparse::BColumns`], so dense
    /// references and the sparse column ops describe one operator.
    pub fn hyperlink(g: &Graph) -> DenseMatrix {
        let n = g.n();
        let mut m = DenseMatrix::zeros(n, n);
        for j in 0..n {
            let deg = g.out_degree(j);
            if deg == 0 {
                m.set(j, j, 1.0);
                continue;
            }
            let w = 1.0 / deg as f64;
            for &i in g.out(j) {
                m.set(i as usize, j, w);
            }
        }
        m
    }

    /// `B = I - αA` for the graph (paper §II-B).
    pub fn b_matrix(g: &Graph, alpha: f64) -> DenseMatrix {
        let mut m = DenseMatrix::hyperlink(g);
        for v in m.data.iter_mut() {
            *v *= -alpha;
        }
        for i in 0..m.rows {
            let v = m.get(i, i) + 1.0;
            m.set(i, i, v);
        }
        m
    }

    /// The perturbed matrix `M = αA + (1-α)/N 𝟙𝟙ᵀ` (Definition 1).
    pub fn google_matrix(g: &Graph, alpha: f64) -> DenseMatrix {
        let n = g.n();
        let mut m = DenseMatrix::hyperlink(g);
        let tele = (1.0 - alpha) / n as f64;
        for v in m.data.iter_mut() {
            *v = alpha * *v + tele;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Borrow column `j` as a slice (column-major payoff).
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Raw column-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// y = self · x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for j in 0..self.cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            let col = self.col(j);
            for i in 0..self.rows {
                y[i] += col[i] * xj;
            }
        }
        y
    }

    /// y = selfᵀ · x
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        (0..self.cols)
            .map(|j| crate::linalg::vector::dot(self.col(j), x))
            .collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> DenseMatrix {
        DenseMatrix::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// self · other (naive; reference scales only).
    pub fn matmul(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, other.rows);
        let mut out = DenseMatrix::zeros(self.rows, other.cols);
        for j in 0..other.cols {
            let y = self.matvec(other.col(j));
            for i in 0..self.rows {
                out.set(i, j, y[i]);
            }
        }
        out
    }

    /// Per-column squared norms `{‖B(:,k)‖²}` — the paper's Remark 3
    /// pre-processing step.
    pub fn column_norms_sq(&self) -> Vec<f64> {
        (0..self.cols)
            .map(|j| crate::linalg::vector::norm2_sq(self.col(j)))
            .collect()
    }

    /// Column-normalized copy `B̂` (each column scaled to unit l2 norm).
    pub fn column_normalized(&self) -> DenseMatrix {
        let mut out = self.clone();
        for j in 0..out.cols {
            let nrm = crate::linalg::vector::norm2(self.col(j));
            assert!(nrm > 0.0, "zero column {j} cannot be normalized");
            let s = 1.0 / nrm;
            for i in 0..out.rows {
                let v = out.get(i, j) * s;
                out.set(i, j, v);
            }
        }
        out
    }

    /// Whether every column sums to 1 (±tol) with non-negative entries.
    pub fn is_column_stochastic(&self, tol: f64) -> bool {
        (0..self.cols).all(|j| {
            let col = self.col(j);
            col.iter().all(|&v| v >= -tol)
                && (crate::linalg::vector::sum(col) - 1.0).abs() <= tol
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn identity_and_access() {
        let m = DenseMatrix::identity(3);
        assert_eq!(m.get(1, 1), 1.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.col(2), &[0.0, 0.0, 1.0]);
    }

    #[test]
    fn hyperlink_is_column_stochastic() {
        let g = generators::er_threshold(60, 0.5, 3);
        let a = DenseMatrix::hyperlink(&g);
        assert!(a.is_column_stochastic(1e-12));
    }

    #[test]
    fn hyperlink_dangling_column_is_self_loop() {
        let g = crate::graph::Graph::from_sorted_edges(3, &[(0, 1), (0, 2), (1, 0)]);
        let a = DenseMatrix::hyperlink(&g); // page 2 is a sink
        assert!(a.is_column_stochastic(1e-12));
        assert_eq!(a.get(2, 2), 1.0);
        assert_eq!(a.get(0, 2), 0.0);
        // exact reference stays finite and well-defined
        let x = crate::linalg::solve::exact_pagerank(&g, 0.85);
        assert!(x.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn hyperlink_matches_graph_entries() {
        let g = generators::star(4);
        let a = DenseMatrix::hyperlink(&g);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(a.get(i, j), g.a_entry(i, j));
            }
        }
    }

    #[test]
    fn b_matrix_definition() {
        let g = generators::ring(4);
        let alpha = 0.85;
        let b = DenseMatrix::b_matrix(&g, alpha);
        let a = DenseMatrix::hyperlink(&g);
        for i in 0..4 {
            for j in 0..4 {
                let expect = if i == j { 1.0 } else { 0.0 } - alpha * a.get(i, j);
                assert!((b.get(i, j) - expect).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn google_matrix_stochastic_and_positive() {
        let g = generators::er_threshold(30, 0.5, 4);
        let m = DenseMatrix::google_matrix(&g, 0.85);
        assert!(m.is_column_stochastic(1e-12));
        assert!(m.data().iter().all(|&v| v > 0.0));
    }

    #[test]
    fn matvec_and_transpose_agree() {
        let m = DenseMatrix::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        let x = vec![1.0, -1.0];
        let y = m.matvec(&x);
        assert_eq!(y, vec![-1.0, -1.0, -1.0]);
        let t = m.transpose();
        let z = t.matvec_t(&x.to_vec());
        // (Mᵀ)ᵀ x = M x
        assert_eq!(z.len(), 3);
        assert_eq!(z, y);
    }

    #[test]
    fn matmul_identity() {
        let g = generators::er_threshold(10, 0.5, 6);
        let a = DenseMatrix::hyperlink(&g);
        let i = DenseMatrix::identity(10);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    #[should_panic(expected = "refusing to allocate a dense")]
    fn corpus_scale_dense_allocation_panics_by_name() {
        let _ = DenseMatrix::zeros(1_000_000, 1_000_000);
    }

    #[test]
    fn column_norms_and_normalization() {
        let m = DenseMatrix::from_fn(2, 2, |i, j| if j == 0 { (i + 1) as f64 } else { 2.0 });
        let n2 = m.column_norms_sq();
        assert_eq!(n2, vec![5.0, 8.0]);
        let hat = m.column_normalized();
        for j in 0..2 {
            assert!((crate::linalg::vector::norm2(hat.col(j)) - 1.0).abs() < 1e-14);
        }
    }
}
