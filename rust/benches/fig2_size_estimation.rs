//! Bench FIG2: regenerates the paper's Figure 2 (Algorithm 2 size
//! estimation, 1000 averaged rounds) and times the estimator step.
//!
//! `cargo bench --bench fig2_size_estimation`

use pagerank_mp::algo::size_estimation::SizeEstimator;
use pagerank_mp::engine::{EstimatorSpec, GraphSpec, Scenario};
use pagerank_mp::graph::generators;
use pagerank_mp::harness::fig2;
use pagerank_mp::util::bench;
use pagerank_mp::util::rng::Rng;

fn main() {
    let quick = bench::quick_mode();
    println!("=== FIG2: network-size estimation (paper Appendix) ===\n");
    let cfg = if quick {
        fig2::Fig2Config { n: 40, rounds: 50, steps: 6_000, stride: 100, ..Default::default() }
    } else {
        fig2::Fig2Config::default()
    };
    let t0 = std::time::Instant::now();
    let res = fig2::run(&cfg);
    println!("{}", res.render());
    for (claim, ok) in res.claims() {
        println!("[{}] {claim}", if ok { "PASS" } else { "FAIL" });
    }
    println!("\nfig2 experiment wall time: {:?}\n", t0.elapsed());
    pagerank_mp::harness::report::write_file(
        std::path::Path::new("reports/fig2.csv"),
        &res.to_csv(),
    )
    .expect("write fig2 csv");

    // The engine's estimator race: Algorithm 2's uniform sites vs the
    // degree-weighted and random-walk baselines, through run-scenario's
    // exact code path (the examples/fig2_scenario.json shape).
    println!("=== estimator race: kaczmarz vs degree vs walk ===");
    let race = Scenario::new("fig2-race", GraphSpec::paper(if quick { 40 } else { 100 }))
        .with_estimators(EstimatorSpec::all())
        .with_steps(if quick { 6_000 } else { 20_000 })
        .with_stride(if quick { 100 } else { 200 })
        .with_rounds(if quick { 20 } else { 200 })
        .with_seed(2017)
        .run()
        .expect("estimator race runs");
    println!("{}", race.render());
    println!("decay-rate ordering (fastest first):");
    for (i, (key, rate)) in race.rate_ordering().into_iter().enumerate() {
        println!("  #{} {:<12} rate/step {rate:.6}", i + 1, key);
    }
    println!();

    println!("=== Algorithm 2 step cost across topologies ===");
    let mut b = bench::standard();
    for (name, g) in [
        ("er-threshold N=100", generators::er_threshold(100, 0.5, 5)),
        ("ring N=100", generators::ring(100)),
        ("star N=100", generators::star(100)),
    ] {
        let mut est = SizeEstimator::new(&g).expect("connected");
        let mut rng = Rng::seeded(9);
        b.bench(&format!("size-est step, {name}"), Some(1.0), || {
            std::hint::black_box(est.step(&mut rng));
        });
    }
    println!("\n{}", b.to_csv());
}
