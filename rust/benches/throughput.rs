//! PERF-L3: activation throughput of the hot paths.
//!
//! * matrix-form Algorithm 1 (the in-process production path),
//! * the distributed coordinator (sequential and async, with latency),
//! * centralized power-iteration sweeps,
//! * batch throughput of the parallel extension.
//!
//! All solvers are named and built through the engine registry — the
//! bench measures exactly what a `Scenario` would run.
//!
//! `cargo bench --bench throughput`

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::engine::{CoordinatorSolver, SolverSpec};
use pagerank_mp::graph::generators;
use pagerank_mp::util::bench;
use pagerank_mp::util::rng::Rng;

fn main() {
    let mut b = bench::standard();
    println!("=== PERF-L3: matrix-form MP activations/s ===");
    for (name, g) in [
        ("paper N=100 (dense)", generators::er_threshold(100, 0.5, 1)),
        ("paper N=1000 (dense)", generators::er_threshold(1000, 0.5, 1)),
        ("ba N=10000 m=8", generators::barabasi_albert(10_000, 8, 1)),
        ("er-sparse N=100000 deg~8", generators::erdos_renyi(100_000, 8.0 / 100_000.0, 1)),
    ] {
        let mut mp = SolverSpec::Mp.build(&g, 0.85, 2);
        let mut rng = Rng::seeded(2);
        let batch = 1024;
        b.bench(&format!("mp x{batch} acts, {name}"), Some(batch as f64), || {
            for _ in 0..batch {
                std::hint::black_box(mp.step(&mut rng));
            }
        });
    }

    println!("\n=== PERF-L3: distributed coordinator activations/s ===");
    for (name, spec) in [
        ("sequential/zero-latency", "coordinator:sequential:uniform:zero"),
        ("sequential/exp-latency", "coordinator:sequential:uniform:exp:0.1"),
        ("async/clocks/const-latency", "coordinator:async:clocks:const:0.1"),
    ] {
        let g = generators::er_threshold(100, 0.5, 3);
        let spec = SolverSpec::parse(spec).expect("registry spec");
        let mut coord = CoordinatorSolver::from_spec(&g, 0.85, 4, &spec).expect("coordinator");
        let batch = 512u64;
        b.bench(&format!("coordinator x{batch} acts, {name}"), Some(batch as f64), || {
            std::hint::black_box(coord.drive(batch));
        });
    }

    println!("\n=== baseline: centralized power-iteration sweeps ===");
    for (name, g) in [
        ("paper N=100", generators::er_threshold(100, 0.5, 5)),
        ("ba N=10000 m=8", generators::barabasi_albert(10_000, 8, 5)),
    ] {
        let mut pi = SolverSpec::PowerIteration.build(&g, 0.85, 5);
        let mut rng = Rng::seeded(5);
        let m = g.m() as f64;
        b.bench(&format!("jacobi sweep (m edges), {name}"), Some(m), || {
            std::hint::black_box(pi.step(&mut rng));
        });
    }

    println!("\n=== sharded multi-threaded runtime (real parallelism) ===");
    // Built through the registry — the bench measures exactly what a
    // `Scenario` listing "sharded:<shards>:64:<map>" would run; the
    // mod-vs-block pair quantifies the shard-map hotspot on a hub-heavy
    // (preferential-attachment) graph.
    for (shards, map) in [(1usize, "mod"), (2, "mod"), (4, "mod"), (8, "mod"), (8, "block")] {
        let g = generators::barabasi_albert(20_000, 8, 8);
        let spec = SolverSpec::parse(&format!("sharded:{shards}:64:{map}")).expect("registry spec");
        let mut rt = spec.build(&g, 0.85, 8);
        let mut rng = Rng::seeded(9);
        let batches = 64;
        b.bench(
            &format!("sharded:{shards}:64:{map}, {batches} super-steps"),
            Some((batches * 64) as f64),
            || {
                for _ in 0..batches {
                    std::hint::black_box(rt.step(&mut rng));
                }
            },
        );
    }

    println!("\n=== dense backend: sweeps/s (O(N²) per sweep) ===");
    for n in [100usize, 400] {
        let g = generators::er_threshold(n, 0.5, 10);
        let mut dense = SolverSpec::Dense.build(&g, 0.85, 10);
        let mut rng = Rng::seeded(10);
        b.bench(&format!("dense sweep N={n}"), Some((n * n) as f64), || {
            std::hint::black_box(dense.step(&mut rng));
        });
    }

    println!("\n=== parallel extension: batched activations ===");
    let g = generators::erdos_renyi(10_000, 8.0 / 10_000.0, 6);
    for batch in [1usize, 8, 32, 128] {
        let mut pmp = SolverSpec::ParallelMp { batch }.build(&g, 0.85, 7);
        let mut rng = Rng::seeded(7);
        b.bench(&format!("parallel-mp batch={batch} (sparse N=10k)"), Some(batch as f64), || {
            std::hint::black_box(pmp.step(&mut rng));
        });
    }

    println!("\n{}", b.to_csv());
    pagerank_mp::harness::report::write_file(
        std::path::Path::new("reports/throughput.csv"),
        &b.to_csv(),
    )
    .expect("write csv");
}
