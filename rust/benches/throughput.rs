//! PERF-L3: activation throughput of the hot paths.
//!
//! * matrix-form Algorithm 1 (the in-process production path),
//! * the distributed coordinator (sequential and async, with latency),
//! * centralized power-iteration sweeps,
//! * batch throughput of the parallel extension,
//! * **leader-saturation**: the sharded runtime swept over shards ∈
//!   {1,2,4,8,16,32} under both packing policies × both sampling
//!   policies (uniform and residual-weighted), recording applied
//!   activations/s into the machine-readable `BENCH_throughput.json`
//!   (the leader packer flattens once its serial sample+scan+route loop
//!   saturates; the worker packer keeps scaling; residual sampling pays
//!   the weight-tree refresh for fewer activations to a given error),
//! * **msgpass-sweep**: the message-passing backend raced to a fixed
//!   residual ε against the shared-memory worker packer on the same
//!   {1,2,4,8}-shard grid, recording messages sent, bytes on the wire,
//!   peak queue depth and virtual-time-to-ε into `BENCH_network.json`
//!   (the communication-cost ledger the sharded runtime, reading shared
//!   memory for free, cannot produce).
//!
//! All solvers are named and built through the engine registry — the
//! bench measures exactly what a `Scenario` would run.
//!
//! * **webgraph**: the corpus-scale pipeline — generate (or reuse) a
//!   million-page synthetic webgraph on disk, measure streaming text
//!   ingest vs the `.csrbin` binary cache (`load_ms`, `graph_bytes`,
//!   `peak_rss_bytes`), then race mp:residual (in-link-free graph), the
//!   sharded worker runtime and the message-passing backend on it,
//!   merging cells into `BENCH_throughput.json`.
//!
//! * **faults**: the degradation curve — msgpass driven to a fixed ε
//!   under per-link drop ∈ {0, 0.01, 0.05, 0.2} × {raw, rel} delivery
//!   plus a drop+mid-run-crash pair, recording vtime-to-ε,
//!   bytes-on-wire and the fault ledger into `BENCH_faults.json` (the
//!   reliable protocol's overhead vs the raw wire's honest stall).
//!
//! * **partitions**: partition tolerance — raw vs reliable msgpass
//!   across an asymmetric link window, a healing shard bipartition and
//!   two overlapping crash windows, each × drop ∈ {0, 0.05}, recording
//!   the fault ledger plus the divergence gauges sampled at partition
//!   onset and heal into `BENCH_partitions.json` (reliable must drain
//!   to convergence with zero abandoned frames after every heal).
//!
//! * **locality**: the shard-map race — mod/block/cluster/scc on
//!   clustered (SBM), hub-heavy (webgraph) and homogeneous (ER)
//!   families, sharded worker cells timing the intra/cross conflict
//!   split and msgpass cells running to ε for bytes-on-wire and
//!   subscriber fan-out, into `BENCH_locality.json` (topology-aware
//!   maps must cut cross-shard traffic where community structure
//!   exists, and cost nothing where it does not).
//!
//! `cargo bench --bench throughput`. Env knobs:
//! `PAGERANK_BENCH_QUICK=1` shrinks every section to a CI smoke size;
//! `THROUGHPUT_ONLY=sharded-sweep` runs only the leader-saturation
//! section, `THROUGHPUT_ONLY=network-sweep` only the msgpass race,
//! `THROUGHPUT_ONLY=webgraph` only the corpus pipeline,
//! `THROUGHPUT_ONLY=faults` only the degradation curve,
//! `THROUGHPUT_ONLY=partitions` only the partition-tolerance race,
//! `THROUGHPUT_ONLY=locality` only the shard-map race (CI runs all
//! six on every push to keep the `bench-json` artifact fed).

use std::collections::BTreeMap;

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::coordinator::msgpass::DEFAULT_GOSSIP_PERIOD;
use pagerank_mp::coordinator::{MsgpassConfig, MsgpassRuntime, Packer, Sampling, ShardMap};
use pagerank_mp::engine::{CoordinatorSolver, ShardedSolver, SolverSpec};
use pagerank_mp::graph::{generators, io as graph_io, DanglingPolicy, LoadOptions};
use pagerank_mp::linalg::vector;
use pagerank_mp::network::{CrashWindow, FaultPlan, LatencyModel, LinkWindow, PartitionWindow};
use pagerank_mp::util::bench;
use pagerank_mp::util::json::Json;
use pagerank_mp::util::rng::Rng;

/// One timed cell of the leader-saturation sweep: warm up, then time
/// `super_steps` super-steps and report *applied* activations per second
/// (the honest number — conflicts thin the budget).
fn sharded_sweep_cell(
    g: &pagerank_mp::graph::Graph,
    shards: usize,
    batch: usize,
    packer: Packer,
    sampling: Sampling,
    super_steps: usize,
) -> Json {
    // Uniform cells keep their PR-3 era spec keys (no sampling segment),
    // so bench_diff can compare across the policy's introduction.
    let spec_key = match sampling {
        Sampling::Uniform => format!("sharded:{shards}:{batch}:mod:{}", packer.key()),
        Sampling::Residual => {
            format!("sharded:{shards}:{batch}:mod:{}:residual", packer.key())
        }
    };
    let mut sh = ShardedSolver::new(g, 0.85, shards, batch, ShardMap::Modulo, packer, sampling);
    let mut rng = Rng::seeded(13);
    for _ in 0..super_steps / 4 {
        sh.step(&mut rng); // warm-up: fault pages, fill buffer pools
    }
    // Snapshot both counters so every reported number covers exactly the
    // timed window (the warm-up above also activates and conflicts).
    let act0 = sh.runtime().activations();
    let conf0 = sh.conflicts();
    let t0 = std::time::Instant::now();
    for _ in 0..super_steps {
        std::hint::black_box(sh.step(&mut rng));
    }
    let wall = t0.elapsed();
    let applied = sh.runtime().activations() - act0;
    let conflicts = sh.conflicts() - conf0;
    let acts_per_sec = applied as f64 / wall.as_secs_f64();
    println!(
        "{spec_key:<28} {super_steps:>5} super-steps  applied {applied:>8}  \
         conflicts {conflicts:>8}  {:>10}/s",
        bench::format_count(acts_per_sec),
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec_key));
    cell.insert("shards".to_string(), Json::Number(shards as f64));
    cell.insert("packer".to_string(), Json::String(packer.key().to_string()));
    cell.insert("sampling".to_string(), Json::String(sampling.key().to_string()));
    cell.insert("super_steps".to_string(), Json::Number(super_steps as f64));
    cell.insert("activations".to_string(), Json::Number(applied as f64));
    cell.insert("conflicts".to_string(), Json::Number(conflicts as f64));
    cell.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    cell.insert("acts_per_sec".to_string(), Json::Number(acts_per_sec));
    Json::Object(cell)
}

/// The leader-saturation measurement (ROADMAP "measure leader-bound
/// throughput at 16+ shards"): sweep shards × packer on a sparse graph
/// big enough that activations are real work, dump
/// `BENCH_throughput.json` for the CI artifact and `scripts/bench_diff`.
fn sharded_saturation_sweep(quick: bool) {
    println!("\n=== leader-saturation: sharded (packer × sampling) × shards sweep ===");
    let (n, batch, super_steps) = if quick {
        (20_000usize, 256usize, 24usize)
    } else {
        (200_000, 1024, 48)
    };
    let g = generators::erdos_renyi(n, 8.0 / n as f64, 12);
    let graph_key = format!("er-sparse N={n} deg~8");
    let mut cells = Vec::new();
    for (packer, sampling) in [
        (Packer::Leader, Sampling::Uniform),
        (Packer::Worker, Sampling::Uniform),
        (Packer::Leader, Sampling::Residual),
        (Packer::Worker, Sampling::Residual),
    ] {
        for shards in [1usize, 2, 4, 8, 16, 32] {
            cells.push(sharded_sweep_cell(&g, shards, batch, packer, sampling, super_steps));
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert(
        "bench".to_string(),
        Json::String("throughput.sharded_sweep".to_string()),
    );
    doc.insert("graph".to_string(), Json::String(graph_key));
    doc.insert("batch".to_string(), Json::Number(batch as f64));
    doc.insert("cells".to_string(), Json::Array(cells));
    // Anchor at the repo root (the bench binary's cwd is the package
    // dir, rust/), so CI's artifact upload and bench_diff find the file
    // next to BENCH_scenario.json / BENCH_sweep.json.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package sits inside the repo")
        .join("BENCH_throughput.json");
    pagerank_mp::harness::report::write_file(&out, &Json::Object(doc).render())
        .expect("write BENCH_throughput.json");
    println!("wrote {}", out.display());
}

/// One msgpass cell of the network race: run to the scaled residual
/// target `(1/N)‖r‖² ≤ eps` and report the communication ledger alongside
/// throughput. `spec_key` carries a `+exp0.1`-style suffix for non-zero
/// latency variants (an artifact key, not a registry key — the registry
/// always builds msgpass at zero latency).
fn msgpass_race_cell(
    g: &pagerank_mp::graph::Graph,
    shards: usize,
    batch: usize,
    latency: LatencyModel,
    latency_key: &str,
    eps: f64,
    max_super_steps: usize,
) -> Json {
    let spec_key = if matches!(latency, LatencyModel::Zero) {
        format!("msgpass:{shards}:{batch}:mod")
    } else {
        format!("msgpass:{shards}:{batch}:mod+{latency_key}")
    };
    let mut rt = MsgpassRuntime::new(g.clone(), 0.85, shards, batch, ShardMap::Modulo, 8, latency);
    let mut rng = Rng::seeded(17);
    let t0 = std::time::Instant::now();
    // A drain failure (possible only under a fault plan; these cells run
    // fault-free) is reported as an honest non-converged cell, not a
    // bench abort.
    let (super_steps, error) = match rt.run_to_residual(eps, max_super_steps, &mut rng) {
        Ok(steps) => (steps, None),
        Err(e) => (max_super_steps, Some(format!("{e:#}"))),
    };
    let wall = t0.elapsed();
    let converged = error.is_none() && rt.residual_norm_sq() / g.n() as f64 <= eps;
    if let Some(e) = &error {
        println!("  WARNING: {spec_key} failed to drain: {e}");
    } else if !converged {
        println!("  WARNING: {spec_key} hit the {max_super_steps}-super-step cap before eps");
    }
    let acts_per_sec = rt.activations() as f64 / wall.as_secs_f64();
    println!(
        "{spec_key:<30} {super_steps:>6} super-steps  msgs {:>9}  bytes {:>11}  \
         vtime {:>9.1}  {:>10}/s",
        rt.messages_sent(),
        rt.bytes_on_wire(),
        rt.virtual_time(),
        bench::format_count(acts_per_sec),
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec_key));
    cell.insert("backend".to_string(), Json::String("msgpass".to_string()));
    cell.insert("shards".to_string(), Json::Number(shards as f64));
    cell.insert("batch".to_string(), Json::Number(batch as f64));
    cell.insert("latency".to_string(), Json::String(latency_key.to_string()));
    cell.insert("eps".to_string(), Json::Number(eps));
    cell.insert("converged".to_string(), Json::Bool(converged));
    cell.insert("super_steps".to_string(), Json::Number(super_steps as f64));
    cell.insert("activations".to_string(), Json::Number(rt.activations() as f64));
    cell.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    cell.insert("acts_per_sec".to_string(), Json::Number(acts_per_sec));
    cell.insert("messages_sent".to_string(), Json::Number(rt.messages_sent() as f64));
    cell.insert("bytes_on_wire".to_string(), Json::Number(rt.bytes_on_wire() as f64));
    cell.insert("vtime_to_eps".to_string(), Json::Number(rt.virtual_time()));
    cell.insert("peak_queue_depth".to_string(), Json::Number(rt.peak_queue_depth() as f64));
    cell.insert("peak_in_flight".to_string(), Json::Number(rt.peak_in_flight() as f64));
    if let Some(e) = error {
        cell.insert("error".to_string(), Json::String(e));
    }
    Json::Object(cell)
}

/// The shared-memory opponent in the network race: the worker-packing
/// sharded runtime driven to the same residual target. It sends no
/// messages (shards read each other through shared memory), so its wire
/// columns are zero and its virtual-time-to-ε is the idealized lockstep
/// count — one time unit per super-step.
fn sharded_race_cell(
    g: &pagerank_mp::graph::Graph,
    shards: usize,
    batch: usize,
    eps: f64,
    max_super_steps: usize,
) -> Json {
    let spec_key = format!("sharded:{shards}:{batch}:mod:worker");
    let n = g.n() as f64;
    let (packer, sampling) = (Packer::Worker, Sampling::Uniform);
    let mut sh = ShardedSolver::new(g, 0.85, shards, batch, ShardMap::Modulo, packer, sampling);
    let mut rng = Rng::seeded(17);
    let mut super_steps = 0usize;
    let t0 = std::time::Instant::now();
    while super_steps < max_super_steps && vector::norm2_sq(&sh.runtime().residual()) / n > eps {
        sh.step(&mut rng);
        super_steps += 1;
    }
    let wall = t0.elapsed();
    let converged = vector::norm2_sq(&sh.runtime().residual()) / n <= eps;
    if !converged {
        println!("  WARNING: {spec_key} hit the {max_super_steps}-super-step cap before eps");
    }
    let applied = sh.runtime().activations();
    let acts_per_sec = applied as f64 / wall.as_secs_f64();
    println!(
        "{spec_key:<30} {super_steps:>6} super-steps  msgs {:>9}  bytes {:>11}  \
         vtime {:>9.1}  {:>10}/s",
        0,
        0,
        super_steps as f64,
        bench::format_count(acts_per_sec),
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec_key));
    cell.insert("backend".to_string(), Json::String("sharded".to_string()));
    cell.insert("shards".to_string(), Json::Number(shards as f64));
    cell.insert("batch".to_string(), Json::Number(batch as f64));
    cell.insert("latency".to_string(), Json::String("shared-memory".to_string()));
    cell.insert("eps".to_string(), Json::Number(eps));
    cell.insert("converged".to_string(), Json::Bool(converged));
    cell.insert("super_steps".to_string(), Json::Number(super_steps as f64));
    cell.insert("activations".to_string(), Json::Number(applied as f64));
    cell.insert("conflicts".to_string(), Json::Number(sh.conflicts() as f64));
    cell.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    cell.insert("acts_per_sec".to_string(), Json::Number(acts_per_sec));
    cell.insert("messages_sent".to_string(), Json::Number(0.0));
    cell.insert("bytes_on_wire".to_string(), Json::Number(0.0));
    cell.insert("vtime_to_eps".to_string(), Json::Number(super_steps as f64));
    cell.insert("peak_queue_depth".to_string(), Json::Number(0.0));
    cell.insert("peak_in_flight".to_string(), Json::Number(0.0));
    Json::Object(cell)
}

/// The msgpass-vs-sharded network race (ISSUE 6): both backends driven to
/// the same scaled residual ε on the same sparse graph over the
/// {1,2,4,8}-shard grid, plus exponential-latency msgpass variants (at
/// one shard latency is moot — no messages exist — so the variant is
/// skipped there). Dumps `BENCH_network.json` for the CI artifact and
/// `scripts/bench_diff`.
fn network_msgpass_sweep(quick: bool) {
    println!("\n=== network race: msgpass vs sharded to residual eps ===");
    let (n, batch, eps, max_super_steps) = if quick {
        (2_000usize, 64usize, 1e-6f64, 20_000usize)
    } else {
        (20_000, 256, 1e-8, 100_000)
    };
    let g = generators::erdos_renyi(n, 8.0 / n as f64, 12);
    let graph_key = format!("er-sparse N={n} deg~8");
    let mut cells = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let zero = LatencyModel::Zero;
        cells.push(msgpass_race_cell(&g, shards, batch, zero, "zero", eps, max_super_steps));
    }
    for shards in [2usize, 4, 8] {
        cells.push(msgpass_race_cell(
            &g,
            shards,
            batch,
            LatencyModel::Exponential { mean: 0.1 },
            "exp0.1",
            eps,
            max_super_steps,
        ));
    }
    for shards in [1usize, 2, 4, 8] {
        cells.push(sharded_race_cell(&g, shards, batch, eps, max_super_steps));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::String("throughput.network_sweep".to_string()));
    doc.insert("graph".to_string(), Json::String(graph_key));
    doc.insert("batch".to_string(), Json::Number(batch as f64));
    doc.insert("eps".to_string(), Json::Number(eps));
    doc.insert("cells".to_string(), Json::Array(cells));
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package sits inside the repo")
        .join("BENCH_network.json");
    pagerank_mp::harness::report::write_file(&out, &Json::Object(doc).render())
        .expect("write BENCH_network.json");
    println!("wrote {}", out.display());
}

/// One cell of the fault-degradation curve: the msgpass backend under a
/// seeded [`FaultPlan`], raced to the scaled residual target in one
/// reliability mode. The spec key is the canonical registry key
/// (`msgpass:4:256:mod:drop0.05:crash1@400+200:rel`), so `bench_diff`
/// tracks each (plan, mode) cell across commits and a scenario could
/// re-run the exact same configuration.
fn faults_race_cell(
    g: &pagerank_mp::graph::Graph,
    shards: usize,
    batch: usize,
    plan: FaultPlan,
    reliable: bool,
    eps: f64,
    max_super_steps: usize,
) -> Json {
    let spec = SolverSpec::Msgpass {
        shards,
        batch,
        map: ShardMap::Modulo,
        gossip: DEFAULT_GOSSIP_PERIOD,
        drop: plan.drop,
        crashes: plan.crashes.clone(),
        links: plan.links.clone(),
        partitions: plan.partitions.clone(),
        reliable,
    };
    let spec_key = spec.key();
    let mut cfg =
        MsgpassConfig::new(shards, batch, ShardMap::Modulo, DEFAULT_GOSSIP_PERIOD, LatencyModel::Zero)
            .with_faults(plan.clone());
    if reliable {
        cfg = cfg.reliable();
    }
    let mut rt = MsgpassRuntime::with_config(g.clone(), 0.85, cfg);
    let mut rng = Rng::seeded(17);
    let t0 = std::time::Instant::now();
    // An undrainable queue (pathological plan) is an honest failed cell,
    // not a bench abort — the degradation curve must show it.
    let (super_steps, error) = match rt.run_to_residual(eps, max_super_steps, &mut rng) {
        Ok(steps) => (steps, None),
        Err(e) => (max_super_steps, Some(format!("{e:#}"))),
    };
    let wall = t0.elapsed();
    let final_residual = rt.residual_norm_sq() / g.n() as f64;
    let converged = error.is_none() && final_residual <= eps;
    if let Some(e) = &error {
        println!("  WARNING: {spec_key} failed to drain: {e}");
    } else if !converged {
        // Expected for raw mode under loss: the honest degradation.
        println!("  note: {spec_key} stopped at residual {final_residual:.3e} (eps {eps:.0e})");
    }
    let f = rt.fault_counters();
    println!(
        "{spec_key:<48} {super_steps:>6} super-steps  vtime {:>9.1}  bytes {:>11}  \
         drop {:>7}  retx {:>6}  dedup {:>6}",
        rt.virtual_time(),
        rt.bytes_on_wire(),
        f.messages_dropped,
        f.retransmits,
        f.duplicates_suppressed,
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec_key));
    cell.insert("mode".to_string(), Json::String(if reliable { "rel" } else { "raw" }.into()));
    cell.insert("drop".to_string(), Json::Number(plan.drop));
    cell.insert("crashed".to_string(), Json::Bool(!plan.crashes.is_empty()));
    cell.insert("shards".to_string(), Json::Number(shards as f64));
    cell.insert("batch".to_string(), Json::Number(batch as f64));
    cell.insert("eps".to_string(), Json::Number(eps));
    cell.insert("converged".to_string(), Json::Bool(converged));
    cell.insert("final_residual".to_string(), Json::Number(final_residual));
    cell.insert("super_steps".to_string(), Json::Number(super_steps as f64));
    cell.insert("vtime_to_eps".to_string(), Json::Number(rt.virtual_time()));
    cell.insert("messages_sent".to_string(), Json::Number(rt.messages_sent() as f64));
    cell.insert("bytes_on_wire".to_string(), Json::Number(rt.bytes_on_wire() as f64));
    cell.insert("messages_dropped".to_string(), Json::Number(f.messages_dropped as f64));
    cell.insert(
        "duplicates_suppressed".to_string(),
        Json::Number(f.duplicates_suppressed as f64),
    );
    cell.insert("retransmits".to_string(), Json::Number(f.retransmits as f64));
    cell.insert("recoveries".to_string(), Json::Number(f.recoveries as f64));
    cell.insert("link_downs".to_string(), Json::Number(f.link_downs as f64));
    cell.insert("partitions_healed".to_string(), Json::Number(f.partitions_healed as f64));
    cell.insert("rtt_estimate".to_string(), Json::Number(f.rtt_estimate));
    cell.insert(
        "residual_divergence_at_crash".to_string(),
        Json::Number(f.residual_divergence_at_crash),
    );
    let (div_onset, div_heal) = rt.partition_divergence();
    cell.insert("partition_divergence_onset".to_string(), Json::Number(div_onset));
    cell.insert("partition_divergence_heal".to_string(), Json::Number(div_heal));
    cell.insert("abandoned".to_string(), Json::Number(rt.abandoned_messages() as f64));
    cell.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    if let Some(e) = error {
        cell.insert("error".to_string(), Json::String(e));
    }
    Json::Object(cell)
}

/// The fault-degradation curve (ISSUE 8): the msgpass backend driven to
/// a fixed scaled residual ε under drop ∈ {0, 0.01, 0.05, 0.2} × mode ∈
/// {raw, rel}, plus a drop+mid-run-crash pair — vtime-to-ε and
/// bytes-on-wire degrade with loss, `rel` pays wire overhead to keep
/// converging, `raw` reports its stall honestly (`converged: false`,
/// `final_residual` at the cap). Dumps `BENCH_faults.json` for the CI
/// artifact and `scripts/bench_diff`.
fn faults_degradation_sweep(quick: bool) {
    println!("\n=== fault degradation: msgpass raw vs reliable under lossy links ===");
    // Raw lossy cells run to the cap by design (conservation is broken,
    // the residual floors), so the cap bounds this section's wall time.
    let (n, batch, eps, max_super_steps) = if quick {
        (2_000usize, 64usize, 1e-6f64, 10_000usize)
    } else {
        (20_000, 256, 1e-8, 40_000)
    };
    let g = generators::erdos_renyi(n, 8.0 / n as f64, 12);
    let graph_key = format!("er-sparse N={n} deg~8");
    let shards = 4usize;
    let mut cells = Vec::new();
    for reliable in [false, true] {
        for drop in [0.0, 0.01, 0.05, 0.2] {
            let plan = FaultPlan::default().with_drop(drop);
            cells.push(faults_race_cell(&g, shards, batch, plan, reliable, eps, max_super_steps));
        }
    }
    // The recovery pair: 5% loss plus one mid-run crash (vtime advances
    // ~batch/shards per super-step, so [400, 600) lands a few dozen
    // super-steps in — after real residual mass is in flight).
    let crash = CrashWindow { shard: 1, at: 400.0, down_for: 200.0 };
    for reliable in [false, true] {
        let plan = FaultPlan::default().with_drop(0.05).with_crash(crash);
        cells.push(faults_race_cell(&g, shards, batch, plan, reliable, eps, max_super_steps));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::String("throughput.faults".to_string()));
    doc.insert("graph".to_string(), Json::String(graph_key));
    doc.insert("shards".to_string(), Json::Number(shards as f64));
    doc.insert("batch".to_string(), Json::Number(batch as f64));
    doc.insert("eps".to_string(), Json::Number(eps));
    doc.insert("cells".to_string(), Json::Array(cells));
    let out = repo_root().join("BENCH_faults.json");
    pagerank_mp::harness::report::write_file(&out, &Json::Object(doc).render())
        .expect("write BENCH_faults.json");
    println!("wrote {}", out.display());
}

/// Partition tolerance (ISSUE 10): raw vs reliable msgpass across the
/// three partition shapes — an asymmetric one-direction link window, a
/// healing shard bipartition, and two *overlapping* crash windows —
/// each × drop ∈ {0, 0.05}. Reliable cells must converge with zero
/// abandoned frames once the fault heals (the RTT-adaptive retransmit
/// budget is measured in round-trips, so an outage never exhausts it);
/// raw cells report their conservation drift honestly via the
/// divergence gauges sampled at partition onset and heal. Dumps
/// `BENCH_partitions.json` for the CI artifact and `scripts/bench_diff`.
fn partitions_sweep(quick: bool) {
    println!("\n=== partition tolerance: raw vs reliable across fault shapes ===");
    let (n, batch, eps, max_super_steps) = if quick {
        (2_000usize, 64usize, 1e-6f64, 10_000usize)
    } else {
        (20_000, 256, 1e-8, 40_000)
    };
    let g = generators::erdos_renyi(n, 8.0 / n as f64, 12);
    let graph_key = format!("er-sparse N={n} deg~8");
    let shards = 4usize;
    // Windows land mid-run: vtime advances ~batch/shards per super-step,
    // so [400, 600) opens a few dozen super-steps in, once real residual
    // mass is crossing shard boundaries.
    let shapes: Vec<(&str, FaultPlan)> = vec![
        (
            "asymmetric-link",
            FaultPlan::default()
                .with_link(LinkWindow { src: 0, dst: 1, at: 400.0, down_for: 200.0 }),
        ),
        (
            "healing-bipartition",
            FaultPlan::default().with_partition(PartitionWindow::new(vec![0, 1], 400.0, 200.0)),
        ),
        (
            "overlapping-crashes",
            FaultPlan::default()
                .with_crash(CrashWindow { shard: 1, at: 400.0, down_for: 200.0 })
                .with_crash(CrashWindow { shard: 2, at: 500.0, down_for: 200.0 }),
        ),
    ];
    let mut cells = Vec::new();
    for (shape, base) in &shapes {
        for drop in [0.0, 0.05] {
            for reliable in [false, true] {
                let plan = base.clone().with_drop(drop);
                let mut cell =
                    faults_race_cell(&g, shards, batch, plan, reliable, eps, max_super_steps);
                if let Json::Object(m) = &mut cell {
                    m.insert("shape".to_string(), Json::String(shape.to_string()));
                }
                cells.push(cell);
            }
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::String("throughput.partitions".to_string()));
    doc.insert("graph".to_string(), Json::String(graph_key));
    doc.insert("shards".to_string(), Json::Number(shards as f64));
    doc.insert("batch".to_string(), Json::Number(batch as f64));
    doc.insert("eps".to_string(), Json::Number(eps));
    doc.insert("cells".to_string(), Json::Array(cells));
    let out = repo_root().join("BENCH_partitions.json");
    pagerank_mp::harness::report::write_file(&out, &Json::Object(doc).render())
        .expect("write BENCH_partitions.json");
    println!("wrote {}", out.display());
}

/// One sharded cell of the locality race: the worker-packed runtime on
/// one shard map, timed over `super_steps` super-steps. Reports the
/// intra/cross conflict split (the dynamic cost of shard boundaries
/// under optimistic packing), the cross-conflict rate per sampled
/// candidate, and the partition's static cross-edge fraction.
fn locality_sharded_cell(
    g: &pagerank_mp::graph::Graph,
    family: &str,
    shards: usize,
    batch: usize,
    map: ShardMap,
    super_steps: usize,
) -> Json {
    let spec_key = format!("sharded:{shards}:{batch}:{}:worker", map.key());
    let mut sh = ShardedSolver::new(g, 0.85, shards, batch, map, Packer::Worker, Sampling::Uniform);
    let mut rng = Rng::seeded(29);
    let t0 = std::time::Instant::now();
    for _ in 0..super_steps {
        std::hint::black_box(sh.step(&mut rng));
    }
    let wall = t0.elapsed();
    let loc = sh.runtime().locality();
    let applied = sh.runtime().activations();
    let candidates = applied + sh.conflicts();
    let cross_rate = if candidates > 0 {
        loc.cross_conflicts as f64 / candidates as f64
    } else {
        0.0
    };
    let acts_per_sec = applied as f64 / wall.as_secs_f64();
    println!(
        "{family:<9} {spec_key:<32} applied {applied:>8}  intra {:>7}  cross {:>7}  \
         xrate {cross_rate:>7.4}  xedge {:>6.3}",
        loc.intra_conflicts, loc.cross_conflicts, loc.cross_edge_fraction,
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec_key));
    cell.insert("backend".to_string(), Json::String("sharded".to_string()));
    cell.insert("family".to_string(), Json::String(family.to_string()));
    cell.insert("map".to_string(), Json::String(map.key().to_string()));
    cell.insert("shards".to_string(), Json::Number(shards as f64));
    cell.insert("batch".to_string(), Json::Number(batch as f64));
    cell.insert("super_steps".to_string(), Json::Number(super_steps as f64));
    cell.insert("activations".to_string(), Json::Number(applied as f64));
    cell.insert("intra_conflicts".to_string(), Json::Number(loc.intra_conflicts as f64));
    cell.insert("cross_conflicts".to_string(), Json::Number(loc.cross_conflicts as f64));
    cell.insert("cross_conflict_rate".to_string(), Json::Number(cross_rate));
    cell.insert(
        "cross_edge_fraction".to_string(),
        Json::Number(loc.cross_edge_fraction),
    );
    cell.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    cell.insert("acts_per_sec".to_string(), Json::Number(acts_per_sec));
    Json::Object(cell)
}

/// One msgpass cell of the locality race: the backend on one shard map
/// run to the scaled residual target, reporting what the map costs on
/// the wire — cross-shard residual updates, their bytes, and the mean
/// subscriber fan-out per activation.
fn locality_msgpass_cell(
    g: &pagerank_mp::graph::Graph,
    family: &str,
    shards: usize,
    batch: usize,
    map: ShardMap,
    eps: f64,
    max_super_steps: usize,
) -> Json {
    let spec_key = format!("msgpass:{shards}:{batch}:{}", map.key());
    let mut rt = MsgpassRuntime::new(
        g.clone(),
        0.85,
        shards,
        batch,
        map,
        DEFAULT_GOSSIP_PERIOD,
        LatencyModel::Zero,
    );
    let mut rng = Rng::seeded(31);
    let t0 = std::time::Instant::now();
    let (super_steps, error) = match rt.run_to_residual(eps, max_super_steps, &mut rng) {
        Ok(steps) => (steps, None),
        Err(e) => (max_super_steps, Some(format!("{e:#}"))),
    };
    let wall = t0.elapsed();
    let converged = error.is_none() && rt.residual_norm_sq() / g.n() as f64 <= eps;
    if let Some(e) = &error {
        println!("  WARNING: {spec_key} failed to drain: {e}");
    } else if !converged {
        println!("  WARNING: {spec_key} hit the {max_super_steps}-super-step cap before eps");
    }
    let loc = rt.locality();
    let acts = rt.activations();
    let fanout = if acts > 0 {
        loc.subscriber_shard_sum as f64 / acts as f64
    } else {
        0.0
    };
    println!(
        "{family:<9} {spec_key:<32} acts {acts:>9}  xmsgs {:>9}  bytes {:>11}  \
         fanout {fanout:>5.2}  xedge {:>6.3}",
        loc.cross_messages,
        rt.bytes_on_wire(),
        loc.cross_edge_fraction,
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec_key));
    cell.insert("backend".to_string(), Json::String("msgpass".to_string()));
    cell.insert("family".to_string(), Json::String(family.to_string()));
    cell.insert("map".to_string(), Json::String(map.key().to_string()));
    cell.insert("shards".to_string(), Json::Number(shards as f64));
    cell.insert("batch".to_string(), Json::Number(batch as f64));
    cell.insert("eps".to_string(), Json::Number(eps));
    cell.insert("converged".to_string(), Json::Bool(converged));
    cell.insert("super_steps".to_string(), Json::Number(super_steps as f64));
    cell.insert("activations".to_string(), Json::Number(acts as f64));
    cell.insert("cross_messages".to_string(), Json::Number(loc.cross_messages as f64));
    cell.insert("cross_bytes".to_string(), Json::Number(loc.cross_bytes as f64));
    cell.insert("bytes_on_wire".to_string(), Json::Number(rt.bytes_on_wire() as f64));
    cell.insert("subscriber_fanout".to_string(), Json::Number(fanout));
    cell.insert(
        "cross_edge_fraction".to_string(),
        Json::Number(loc.cross_edge_fraction),
    );
    cell.insert("vtime_to_eps".to_string(), Json::Number(rt.virtual_time()));
    cell.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    cell.insert(
        "acts_per_sec".to_string(),
        Json::Number(acts as f64 / wall.as_secs_f64()),
    );
    if let Some(e) = error {
        cell.insert("error".to_string(), Json::String(e));
    }
    Json::Object(cell)
}

/// The shard-map locality race (ISSUE 9): mod/block/cluster/scc on a
/// clustered SBM, the hub-heavy synthetic webgraph and a homogeneous
/// sparse ER graph. Sharded worker cells time the intra/cross conflict
/// split; msgpass cells run to ε and meter the wire. On the SBM the
/// topology-aware maps must land a lower cross-conflict rate and fewer
/// bytes-to-ε than modulo; on the ER graph there is no structure to
/// exploit and the table maps must simply not lose. Dumps
/// `BENCH_locality.json` for the CI artifact and `scripts/bench_diff`.
fn locality_sweep(quick: bool) {
    println!("\n=== locality: shard-map race (mod/block/cluster/scc) ===");
    let (n, batch, super_steps, eps, max_super_steps) = if quick {
        (2_000usize, 64usize, 24usize, 1e-6f64, 20_000usize)
    } else {
        (20_000, 256, 48, 1e-8, 100_000)
    };
    let shards = 4usize;
    let families: Vec<(&str, pagerank_mp::graph::Graph)> = vec![
        // Two planted communities, ~6:1 in:out degree — the structure
        // cluster packing is built to find.
        ("sbm", generators::sbm_two_block(n, 12.0 / n as f64, 2.0 / n as f64, 12)),
        // Hub-heavy synthetic corpus: power-law in-degrees, no planted
        // cut — the hard case for balance-bounded packing.
        ("webgraph", generators::webgraph(n, 12)),
        // Homogeneous sparse ER: nothing to exploit; the control.
        ("er", generators::erdos_renyi(n, 8.0 / n as f64, 12)),
    ];
    let mut cells = Vec::new();
    for (family, g) in &families {
        for map in [ShardMap::Modulo, ShardMap::Block, ShardMap::Cluster, ShardMap::Scc] {
            cells.push(locality_sharded_cell(g, family, shards, batch, map, super_steps));
            cells.push(locality_msgpass_cell(g, family, shards, batch, map, eps, max_super_steps));
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::String("throughput.locality".to_string()));
    doc.insert("n".to_string(), Json::Number(n as f64));
    doc.insert("shards".to_string(), Json::Number(shards as f64));
    doc.insert("batch".to_string(), Json::Number(batch as f64));
    doc.insert("eps".to_string(), Json::Number(eps));
    doc.insert("cells".to_string(), Json::Array(cells));
    let out = repo_root().join("BENCH_locality.json");
    pagerank_mp::harness::report::write_file(&out, &Json::Object(doc).render())
        .expect("write BENCH_locality.json");
    println!("wrote {}", out.display());
}

/// Peak resident set size (`VmHWM` from `/proc/self/status`) in bytes;
/// 0.0 on platforms without procfs — the column is then absent-as-zero
/// rather than fabricated.
fn peak_rss_bytes() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find_map(|l| l.strip_prefix("VmHWM:"))
                .and_then(|rest| rest.trim().trim_end_matches("kB").trim().parse::<f64>().ok())
        })
        .map(|kb| kb * 1024.0)
        .unwrap_or(0.0)
}

/// The repo root (the bench binary's package dir is `rust/`).
fn repo_root() -> &'static std::path::Path {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package sits inside the repo")
}

/// Merge webgraph cells into `BENCH_throughput.json` without clobbering
/// the leader-saturation section: stale `webgraph*` cells are replaced,
/// everything else in the artifact is preserved. (The sharded sweep
/// still rewrites the file wholesale, so CI runs it before this
/// section.)
fn merge_webgraph_cells(new_cells: Vec<Json>) {
    let out = repo_root().join("BENCH_throughput.json");
    let mut doc: BTreeMap<String, Json> = match std::fs::read_to_string(&out)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
    {
        Some(Json::Object(m)) => m,
        _ => {
            let mut m = BTreeMap::new();
            m.insert("bench".to_string(), Json::String("throughput.webgraph".to_string()));
            m
        }
    };
    let mut cells: Vec<Json> = match doc.remove("cells") {
        Some(Json::Array(a)) => a,
        _ => Vec::new(),
    };
    cells.retain(|c| {
        c.get("spec")
            .and_then(Json::as_str)
            .map(|s| !s.starts_with("webgraph"))
            .unwrap_or(true)
    });
    cells.extend(new_cells);
    doc.insert("cells".to_string(), Json::Array(cells));
    pagerank_mp::harness::report::write_file(&out, &Json::Object(doc).render())
        .expect("write BENCH_throughput.json");
    println!("wrote {}", out.display());
}

fn webgraph_load_cell(spec: &str, n: usize, m: usize, load_ms: f64, graph_bytes: usize) -> Json {
    println!(
        "{spec:<30} {n:>9} pages  {m:>10} edges  load {load_ms:>8.1} ms  \
         graph {:>7} B  rss {:>7} B",
        bench::format_count(graph_bytes as f64),
        bench::format_count(peak_rss_bytes()),
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec.to_string()));
    cell.insert("n".to_string(), Json::Number(n as f64));
    cell.insert("edges".to_string(), Json::Number(m as f64));
    cell.insert("load_ms".to_string(), Json::Number(load_ms));
    cell.insert("graph_bytes".to_string(), Json::Number(graph_bytes as f64));
    cell.insert("peak_rss_bytes".to_string(), Json::Number(peak_rss_bytes()));
    Json::Object(cell)
}

fn webgraph_race_cell(
    spec: &str,
    activations: u64,
    wall: std::time::Duration,
    graph_bytes: usize,
) -> Json {
    let acts_per_sec = activations as f64 / wall.as_secs_f64();
    println!(
        "{spec:<30} {activations:>9} acts  {:>8.1} ms  {:>10}/s  graph {:>7} B",
        wall.as_secs_f64() * 1e3,
        bench::format_count(acts_per_sec),
        bench::format_count(graph_bytes as f64),
    );
    let mut cell = BTreeMap::new();
    cell.insert("spec".to_string(), Json::String(spec.to_string()));
    cell.insert("activations".to_string(), Json::Number(activations as f64));
    cell.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    cell.insert("acts_per_sec".to_string(), Json::Number(acts_per_sec));
    cell.insert("graph_bytes".to_string(), Json::Number(graph_bytes as f64));
    cell.insert("peak_rss_bytes".to_string(), Json::Number(peak_rss_bytes()));
    Json::Object(cell)
}

/// The corpus-scale webgraph pipeline (ISSUE 7): generate (or reuse) a
/// million-page synthetic corpus on disk, measure streaming text ingest
/// vs the `.csrbin` binary cache, then race mp:residual (on an
/// in-link-free graph — the lean-storage payoff), the sharded worker
/// runtime and the message-passing backend (which pays for the lazy
/// transpose) on it. Cells merge into `BENCH_throughput.json` next to
/// the leader-saturation sweep. Quick mode shrinks the corpus to 50k
/// pages for the CI smoke gate.
fn webgraph_bench(quick: bool) {
    println!("\n=== webgraph corpus: streaming ingest + corpus-scale race ===");
    let (n, mp_acts, sharded_steps, msgpass_steps) = if quick {
        (50_000usize, 100_000u64, 32usize, 8usize)
    } else {
        (1_000_000, 1_000_000, 64, 16)
    };
    let seed = 2017u64;
    let corpus_dir = repo_root().join("corpus");
    let path = corpus_dir.join(format!("webgraph_{n}_{seed}.txt"));
    if !path.exists() {
        std::fs::create_dir_all(&corpus_dir).expect("create corpus dir");
        let t0 = std::time::Instant::now();
        let f = std::fs::File::create(&path).expect("create corpus file");
        generators::write_webgraph_corpus(n, seed, std::io::BufWriter::new(f))
            .expect("stream corpus to disk");
        println!(
            "generated {} in {:.1}s",
            path.display(),
            t0.elapsed().as_secs_f64()
        );
    }
    // SelfLoop, NOT the LinkAll default: at 10⁶ pages LinkAll would
    // materialize n-1 repair edges per dangling page (~1.8% of the
    // corpus) — an OOM, not a policy.
    let opts = LoadOptions::new(DanglingPolicy::SelfLoop);
    let mut cells = Vec::new();

    // ---- streaming text ingest (two passes, straight into CSR) ----
    let t0 = std::time::Instant::now();
    let g = graph_io::load_with(&path, &opts).expect("corpus loads");
    let text_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(g.n(), n);
    cells.push(webgraph_load_cell("webgraph-load:text", n, g.m(), text_ms, g.memory_bytes()));

    // ---- .csrbin binary cache ----
    let bin = graph_io::csrbin_path(&path);
    graph_io::write_csrbin(&g, &bin, &opts).expect("write csrbin");
    let t0 = std::time::Instant::now();
    let (gbin, bin_opts) = graph_io::read_csrbin(&bin).expect("csrbin loads");
    let bin_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(gbin, g, "csrbin must round-trip the corpus exactly");
    assert_eq!(bin_opts.dangling, opts.dangling);
    cells.push(webgraph_load_cell("webgraph-load:csrbin", n, gbin.m(), bin_ms, gbin.memory_bytes()));
    drop(gbin);

    // ---- race: residual-weighted MP on an in-link-free graph ----
    let lean = g.clone().without_in_links();
    let lean_bytes = lean.memory_bytes();
    let mut mp = SolverSpec::parse("mp:residual").expect("registry").build(&lean, 0.85, 21);
    let mut rng = Rng::seeded(21);
    let t0 = std::time::Instant::now();
    for _ in 0..mp_acts {
        std::hint::black_box(mp.step(&mut rng));
    }
    let wall = t0.elapsed();
    assert!(!lean.in_links_built(), "MP must never touch the transpose");
    cells.push(webgraph_race_cell("webgraph:mp:residual", mp_acts, wall, lean_bytes));
    drop(mp);
    drop(lean);

    // ---- race: sharded worker runtime (out-links only, too) ----
    let batch = 256usize;
    let mut sh =
        ShardedSolver::new(&g, 0.85, 4, batch, ShardMap::Modulo, Packer::Worker, Sampling::Uniform);
    let mut rng = Rng::seeded(22);
    for _ in 0..4 {
        sh.step(&mut rng); // warm-up
    }
    let act0 = sh.runtime().activations();
    let t0 = std::time::Instant::now();
    for _ in 0..sharded_steps {
        std::hint::black_box(sh.step(&mut rng));
    }
    let wall = t0.elapsed();
    let applied = sh.runtime().activations() - act0;
    cells.push(webgraph_race_cell(
        &format!("webgraph:sharded:4:{batch}:mod:worker"),
        applied,
        wall,
        g.memory_bytes(),
    ));
    drop(sh);
    assert!(!g.in_links_built(), "the sharded runtime is out-link only");

    // ---- race: message-passing backend (pays the lazy transpose) ----
    let mut rt =
        MsgpassRuntime::new(g.clone(), 0.85, 2, batch, ShardMap::Modulo, 8, LatencyModel::Zero);
    let mut rng = Rng::seeded(23);
    let t0 = std::time::Instant::now();
    // eps far below reach: the super-step cap governs the budget.
    rt.run_to_residual(1e-300, msgpass_steps, &mut rng)
        .expect("fault-free msgpass runs drain");
    let wall = t0.elapsed();
    // Materialize the transpose on the shared graph to report what an
    // in-link consumer actually holds in memory.
    let _ = g.inc(0);
    cells.push(webgraph_race_cell(
        &format!("webgraph:msgpass:2:{batch}:mod"),
        rt.activations(),
        wall,
        g.memory_bytes(),
    ));

    merge_webgraph_cells(cells);
}

fn main() {
    let quick = bench::quick_mode();
    if std::env::var("THROUGHPUT_ONLY").as_deref() == Ok("sharded-sweep") {
        sharded_saturation_sweep(quick);
        return;
    }
    if std::env::var("THROUGHPUT_ONLY").as_deref() == Ok("network-sweep") {
        network_msgpass_sweep(quick);
        return;
    }
    if std::env::var("THROUGHPUT_ONLY").as_deref() == Ok("webgraph") {
        webgraph_bench(quick);
        return;
    }
    if std::env::var("THROUGHPUT_ONLY").as_deref() == Ok("faults") {
        faults_degradation_sweep(quick);
        return;
    }
    if std::env::var("THROUGHPUT_ONLY").as_deref() == Ok("partitions") {
        partitions_sweep(quick);
        return;
    }
    if std::env::var("THROUGHPUT_ONLY").as_deref() == Ok("locality") {
        locality_sweep(quick);
        return;
    }
    let mut b = bench::standard();
    println!("=== PERF-L3: matrix-form MP activations/s ===");
    for (name, g) in [
        ("paper N=100 (dense)", generators::er_threshold(100, 0.5, 1)),
        ("paper N=1000 (dense)", generators::er_threshold(1000, 0.5, 1)),
        ("ba N=10000 m=8", generators::barabasi_albert(10_000, 8, 1)),
        ("er-sparse N=100000 deg~8", generators::erdos_renyi(100_000, 8.0 / 100_000.0, 1)),
    ] {
        let mut mp = SolverSpec::Mp.build(&g, 0.85, 2);
        let mut rng = Rng::seeded(2);
        let batch = 1024;
        b.bench(&format!("mp x{batch} acts, {name}"), Some(batch as f64), || {
            for _ in 0..batch {
                std::hint::black_box(mp.step(&mut rng));
            }
        });
    }

    println!("\n=== PERF-L3: distributed coordinator activations/s ===");
    for (name, spec) in [
        ("sequential/zero-latency", "coordinator:sequential:uniform:zero"),
        ("sequential/exp-latency", "coordinator:sequential:uniform:exp:0.1"),
        ("async/clocks/const-latency", "coordinator:async:clocks:const:0.1"),
    ] {
        let g = generators::er_threshold(100, 0.5, 3);
        let spec = SolverSpec::parse(spec).expect("registry spec");
        let mut coord = CoordinatorSolver::from_spec(&g, 0.85, 4, &spec).expect("coordinator");
        let batch = 512u64;
        b.bench(&format!("coordinator x{batch} acts, {name}"), Some(batch as f64), || {
            std::hint::black_box(coord.drive(batch));
        });
    }

    println!("\n=== baseline: centralized power-iteration sweeps ===");
    for (name, g) in [
        ("paper N=100", generators::er_threshold(100, 0.5, 5)),
        ("ba N=10000 m=8", generators::barabasi_albert(10_000, 8, 5)),
    ] {
        let mut pi = SolverSpec::PowerIteration.build(&g, 0.85, 5);
        let mut rng = Rng::seeded(5);
        let m = g.m() as f64;
        b.bench(&format!("jacobi sweep (m edges), {name}"), Some(m), || {
            std::hint::black_box(pi.step(&mut rng));
        });
    }

    println!("\n=== sharded multi-threaded runtime (real parallelism) ===");
    // Built through the registry — the bench measures exactly what a
    // `Scenario` listing "sharded:<shards>:64:<map>" would run; the
    // mod-vs-block pair quantifies the shard-map hotspot on a hub-heavy
    // (preferential-attachment) graph.
    for (shards, map) in [(1usize, "mod"), (2, "mod"), (4, "mod"), (8, "mod"), (8, "block")] {
        let g = generators::barabasi_albert(20_000, 8, 8);
        let spec = SolverSpec::parse(&format!("sharded:{shards}:64:{map}")).expect("registry spec");
        let mut rt = spec.build(&g, 0.85, 8);
        let mut rng = Rng::seeded(9);
        let batches = 64;
        b.bench(
            &format!("sharded:{shards}:64:{map}, {batches} super-steps"),
            Some((batches * 64) as f64),
            || {
                for _ in 0..batches {
                    std::hint::black_box(rt.step(&mut rng));
                }
            },
        );
    }

    println!("\n=== dense backend: sweeps/s (O(N²) per sweep) ===");
    for n in [100usize, 400] {
        let g = generators::er_threshold(n, 0.5, 10);
        let mut dense = SolverSpec::Dense.build(&g, 0.85, 10);
        let mut rng = Rng::seeded(10);
        b.bench(&format!("dense sweep N={n}"), Some((n * n) as f64), || {
            std::hint::black_box(dense.step(&mut rng));
        });
    }

    println!("\n=== parallel extension: batched activations ===");
    let g = generators::erdos_renyi(10_000, 8.0 / 10_000.0, 6);
    for batch in [1usize, 8, 32, 128] {
        let mut pmp = SolverSpec::ParallelMp { batch }.build(&g, 0.85, 7);
        let mut rng = Rng::seeded(7);
        b.bench(&format!("parallel-mp batch={batch} (sparse N=10k)"), Some(batch as f64), || {
            std::hint::black_box(pmp.step(&mut rng));
        });
    }

    sharded_saturation_sweep(quick);
    network_msgpass_sweep(quick);
    webgraph_bench(quick);
    faults_degradation_sweep(quick);
    partitions_sweep(quick);
    locality_sweep(quick);

    println!("\n{}", b.to_csv());
    pagerank_mp::harness::report::write_file(
        std::path::Path::new("reports/throughput.csv"),
        &b.to_csv(),
    )
    .expect("write csv");
}
