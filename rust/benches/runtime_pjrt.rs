//! PERF-RT: PJRT dense-engine latency and the sparse/dense crossover.
//!
//! Measures amortized per-activation cost of the AOT-compiled JAX/Pallas
//! chunks against the sparse f64 Rust implementation — quantifying where
//! the dense MXU-shaped formulation would pay off on real accelerator
//! hardware (on CPU-PJRT the interpret-mode kernels are expected to lose;
//! the DESIGN.md §Hardware-Adaptation note estimates the TPU numbers).
//!
//! `cargo bench --bench runtime_pjrt` (requires `make artifacts`)

use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::graph::generators;
use pagerank_mp::runtime::{artifact_dir, Engine, JacobiRunner, MpChunkRunner, SizeChunkRunner};
use pagerank_mp::util::bench;
use pagerank_mp::util::rng::Rng;

fn main() {
    if !artifact_dir().join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts — run `make artifacts` first");
        return;
    }
    let mut engine = Engine::load_default().expect("engine");
    println!("PJRT platform: {}\n", engine.platform());
    let mut b = bench::standard();

    for n in [100usize, 200] {
        let g = generators::er_threshold(n, 0.5, 11);

        let mut runner = MpChunkRunner::new(&mut engine, &g, 0.85).expect("runner");
        let t = runner.chunk_len();
        let mut rng = Rng::seeded(12);
        b.bench(
            &format!("mp_chunk T={t} (P={}) N={n}", runner.padded_size()),
            Some(t as f64),
            || {
                let ks: Vec<usize> = (0..t).map(|_| rng.below(n)).collect();
                std::hint::black_box(runner.run_chunk(&mut engine, &ks).expect("chunk"));
            },
        );

        let mut jac = JacobiRunner::new(&mut engine, &g, 0.85).expect("runner");
        let tj = jac.chunk_len();
        b.bench(&format!("jacobi_chunk T={tj} N={n}"), Some(tj as f64), || {
            jac.run_chunk(&mut engine).expect("chunk");
        });

        let mut size = SizeChunkRunner::new(&mut engine, &g).expect("runner");
        let ts = size.chunk_len();
        let mut rng = Rng::seeded(13);
        b.bench(&format!("size_chunk T={ts} N={n}"), Some(ts as f64), || {
            let ks: Vec<usize> = (0..ts).map(|_| rng.below(n)).collect();
            std::hint::black_box(size.run_chunk(&mut engine, &ks).expect("chunk"));
        });

        // sparse reference on identical workload
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(14);
        b.bench(&format!("sparse mp x{t} acts N={n}"), Some(t as f64), || {
            for _ in 0..t {
                std::hint::black_box(mp.step(&mut rng));
            }
        });
    }

    // crossover summary
    println!("\n=== sparse vs dense per-activation summary ===");
    let rows: Vec<Vec<String>> = b
        .results()
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                bench::format_ns(r.median_ns()),
                r.throughput()
                    .map(|t| format!("{}/s", bench::format_count(t)))
                    .unwrap_or_default(),
            ]
        })
        .collect();
    println!(
        "{}",
        pagerank_mp::harness::report::table(&["case", "median", "steps/s"], &rows)
    );
    pagerank_mp::harness::report::write_file(
        std::path::Path::new("reports/runtime_pjrt.csv"),
        &b.to_csv(),
    )
    .expect("write csv");
}
