//! Ablation bench: the DESIGN.md §4 studies (rate tightness, samplers,
//! parallel batches, greedy-vs-random) as reproducible tables.
//!
//! `cargo bench --bench ablation`

use pagerank_mp::harness::ablation;
use pagerank_mp::harness::report;
use pagerank_mp::util::bench;

fn main() {
    let quick = bench::quick_mode();
    let (n, rounds, steps) = if quick { (40, 5, 8_000) } else { (100, 20, 40_000) };
    let seed = 2017;

    println!("=== ABL-RATE: measured contraction vs 1-σ²(B̂)/N ===");
    let t0 = std::time::Instant::now();
    let rows = ablation::rate_study(n, 0.85, rounds, steps, seed);
    let tbl: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.family.clone(),
                format!("{:.6}", r.predicted_bound),
                format!("{:.6}", r.measured_rate),
                format!("{:.2}x", r.tightness),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["family", "bound", "measured", "tightness"], &tbl)
    );
    println!("({:?})\n", t0.elapsed());

    println!("=== ABL-SAMPLER: §IV-3 non-uniform sampling ===");
    let t0 = std::time::Instant::now();
    let rows = ablation::sampler_study(n, 0.85, if quick { 5_000 } else { 20_000 }, seed);
    let tbl: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.sampler.clone(),
                format!("{:.3e}", r.final_error),
                r.deferred.to_string(),
                format!("{:.1}", r.makespan),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["sampler", "(1/N)|x-x*|²", "deferred", "makespan"], &tbl)
    );
    println!("({:?})\n", t0.elapsed());

    println!("=== ABL-PARALLEL: §IV-1 conflict-free batching ===");
    let t0 = std::time::Instant::now();
    let rows = ablation::parallel_study(
        if quick { 200 } else { 500 },
        0.85,
        &[1, 4, 16, 64],
        &[0.004, 0.02, 0.1],
        if quick { 100 } else { 500 },
        seed,
    );
    let tbl: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.3}", r.density),
                r.requested_batch.to_string(),
                format!("{:.2}", r.effective_batch),
                format!("{:.3e}", r.final_error),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["density", "batch req", "batch eff", "error"], &tbl)
    );
    println!("({:?})\n", t0.elapsed());

    println!("=== ABL-GREEDY: §II-B randomization cost/benefit ===");
    let t0 = std::time::Instant::now();
    let rows = ablation::greedy_study(n, 0.85, if quick { 5_000 } else { 30_000 }, seed);
    let tbl: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.algo.clone(),
                r.iterations.to_string(),
                format!("{:.3e}", r.final_error),
                r.total_reads.to_string(),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["algorithm", "iterations", "error", "total reads"], &tbl)
    );
    println!("({:?})\n", t0.elapsed());

    println!("=== ABL-GREEDY-SCALE: tree-backed best-atom at webgraph sizes ===");
    // The seed implementation's O(N) per-step argmax made this size
    // unusable (10⁵ pages × 10⁴ steps = 10⁹ score reads for selection
    // alone); the MaxScoreTree brings selection down to the touched
    // neighbourhood, asserted below from the rescan counters.
    let t0 = std::time::Instant::now();
    let (scale_n, scale_steps) = if quick { (20_000, 2_000) } else { (100_000, 10_000) };
    let row = ablation::greedy_scale_study(scale_n, 0.85, scale_steps, seed);
    println!(
        "n={} steps={}  rescans: total {} mean {:.1} max {}  residual² {:.3e}  {:.0} ms \
         ({:.0} steps/s)",
        row.n,
        row.steps,
        row.total_rescans,
        row.mean_step_rescans,
        row.max_step_rescans,
        row.final_residual_sq,
        row.wall_ms,
        row.steps as f64 / (row.wall_ms / 1e3),
    );
    assert!(
        row.max_step_rescans < row.n / 10,
        "per-step selection cost must be bounded by the touched neighbourhood, \
         not N: max {} on n={}",
        row.max_step_rescans,
        row.n
    );
    assert!(
        row.total_rescans < (row.steps as u64) * (row.n as u64) / 100,
        "aggregate selection cost {} looks like the old O(N)-per-step scan",
        row.total_rescans
    );
    println!("({:?})", t0.elapsed());
}
