//! Bench FIG1: regenerates the paper's Figure 1 and times the per-step
//! cost of each competitor on the §III workload.
//!
//! `cargo bench --bench fig1_convergence`
//! Set PAGERANK_BENCH_QUICK=1 for a reduced-scale smoke run.

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::engine::{GraphSpec, SolverSpec};
use pagerank_mp::harness::fig1;
use pagerank_mp::util::bench;
use pagerank_mp::util::rng::Rng;

fn main() {
    let quick = bench::quick_mode();
    println!("=== FIG1: convergence trajectories (paper §III) ===\n");
    let cfg = if quick {
        fig1::Fig1Config { n: 40, rounds: 10, steps: 10_000, stride: 200, ..Default::default() }
    } else {
        fig1::Fig1Config::default()
    };
    let t0 = std::time::Instant::now();
    let res = fig1::run(&cfg);
    println!("{}", res.render());
    for (claim, ok) in res.claims() {
        println!("[{}] {claim}", if ok { "PASS" } else { "FAIL" });
    }
    println!("\nfig1 experiment wall time: {:?}\n", t0.elapsed());
    pagerank_mp::harness::report::write_file(
        std::path::Path::new("reports/fig1.csv"),
        &res.to_csv(),
    )
    .expect("write fig1 csv");

    println!("=== per-activation step cost (N=100 paper graph) ===");
    let g = GraphSpec::paper(100).build(7).expect("paper graph builds");
    let mut b = bench::standard();

    for key in ["mp", "you-tempo-qiu", "ishii-tempo"] {
        let spec = SolverSpec::parse(key).expect("registry name");
        let mut solver = spec.build(&g, 0.85, 1);
        let mut rng = Rng::seeded(1);
        b.bench(&format!("{key} step"), Some(1.0), || {
            std::hint::black_box(solver.step(&mut rng));
        });
    }

    println!("\n{}", b.to_csv());
}
