//! Bench FIG1: regenerates the paper's Figure 1 and times the per-step
//! cost of each competitor on the §III workload.
//!
//! `cargo bench --bench fig1_convergence`
//! Set PAGERANK_BENCH_QUICK=1 for a reduced-scale smoke run.

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::ishii_tempo::IshiiTempo;
use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::algo::you_tempo_qiu::YouTempoQiu;
use pagerank_mp::graph::generators;
use pagerank_mp::harness::fig1;
use pagerank_mp::util::bench;
use pagerank_mp::util::rng::Rng;

fn main() {
    let quick = bench::quick_mode();
    println!("=== FIG1: convergence trajectories (paper §III) ===\n");
    let cfg = if quick {
        fig1::Fig1Config { n: 40, rounds: 10, steps: 10_000, stride: 200, ..Default::default() }
    } else {
        fig1::Fig1Config::default()
    };
    let t0 = std::time::Instant::now();
    let res = fig1::run(&cfg);
    println!("{}", res.render());
    for (claim, ok) in res.claims() {
        println!("[{}] {claim}", if ok { "PASS" } else { "FAIL" });
    }
    println!("\nfig1 experiment wall time: {:?}\n", t0.elapsed());
    pagerank_mp::harness::report::write_file(
        std::path::Path::new("reports/fig1.csv"),
        &res.to_csv(),
    )
    .expect("write fig1 csv");

    println!("=== per-activation step cost (N=100 paper graph) ===");
    let g = generators::er_threshold(100, 0.5, 7);
    let mut b = bench::standard();

    let mut mp = MatchingPursuit::new(&g, 0.85);
    let mut rng = Rng::seeded(1);
    b.bench("mp step (Algorithm 1)", Some(1.0), || {
        std::hint::black_box(mp.step(&mut rng));
    });

    let mut ytq = YouTempoQiu::new(&g, 0.85);
    let mut rng = Rng::seeded(2);
    b.bench("you-tempo-qiu [15] step", Some(1.0), || {
        std::hint::black_box(ytq.step(&mut rng));
    });

    let mut it = IshiiTempo::new(&g, 0.85);
    let mut rng = Rng::seeded(3);
    b.bench("ishii-tempo [6] step", Some(1.0), || {
        std::hint::black_box(it.step(&mut rng));
    });

    println!("\n{}", b.to_csv());
}
