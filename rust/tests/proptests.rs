//! Property-based tests (hand-rolled: the offline environment has no
//! proptest crate). Each property is exercised over many seeded random
//! instances; failures print the offending seed so cases replay exactly.

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::algo::parallel_mp::ParallelMatchingPursuit;
use pagerank_mp::algo::size_estimation::SizeEstimator;
use pagerank_mp::coordinator::sampler::WeightTree;
use pagerank_mp::graph::builder::BuildError;
use pagerank_mp::graph::io::{self as graph_io, IoError};
use pagerank_mp::graph::{generators, DanglingPolicy, GraphBuilder, LoadOptions};
use pagerank_mp::linalg::dense::DenseMatrix;
use pagerank_mp::linalg::solve::{exact_pagerank, Lu};
use pagerank_mp::linalg::sparse::BColumns;
use pagerank_mp::linalg::vector;
use pagerank_mp::util::json::Json;
use pagerank_mp::util::rng::Rng;

/// Random graph with guaranteed no dangling pages.
fn random_graph(rng: &mut Rng) -> pagerank_mp::graph::Graph {
    let n = rng.range(5, 60);
    let p = 0.05 + 0.5 * rng.uniform();
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::SelfLoop);
    for s in 0..n {
        for d in 0..n {
            if rng.bernoulli(p) {
                b.add_edge(s, d);
            }
        }
    }
    b.build().expect("random graph builds")
}

/// PROPERTY: eq. 11 conservation — B x_t + r_t = y after any activation
/// sequence on any graph.
#[test]
fn prop_conservation_on_random_graphs() {
    for case in 0..40u64 {
        let mut rng = Rng::seeded(9000 + case);
        let g = random_graph(&mut rng);
        let alpha = 0.2 + 0.75 * rng.uniform();
        let mut mp = MatchingPursuit::new(&g, alpha);
        let steps = rng.range(1, 400);
        for _ in 0..steps {
            mp.step(&mut rng);
        }
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&mp.estimate());
        for (i, (v, r)) in bx.iter().zip(mp.residual()).enumerate() {
            assert!(
                (v + r - (1.0 - alpha)).abs() < 1e-9,
                "case {case}: conservation broken at page {i}"
            );
        }
    }
}

/// PROPERTY: ‖r‖ is non-increasing pathwise for any graph/α/sequence.
#[test]
fn prop_residual_monotone() {
    for case in 0..40u64 {
        let mut rng = Rng::seeded(9100 + case);
        let g = random_graph(&mut rng);
        let alpha = 0.2 + 0.75 * rng.uniform();
        let mut mp = MatchingPursuit::new(&g, alpha);
        let mut prev = mp.residual_norm_sq();
        for _ in 0..300 {
            mp.step(&mut rng);
            let cur = mp.residual_norm_sq();
            assert!(cur <= prev + 1e-12, "case {case}: residual grew");
            prev = cur;
        }
    }
}

/// PROPERTY: incremental ‖r‖² tracking equals the exact recomputation.
#[test]
fn prop_incremental_norm_exact() {
    for case in 0..25u64 {
        let mut rng = Rng::seeded(9200 + case);
        let g = random_graph(&mut rng);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        for _ in 0..rng.range(10, 500) {
            mp.step(&mut rng);
        }
        let exact = vector::norm2_sq(mp.residual());
        assert!(
            (mp.residual_norm_sq() - exact).abs() <= 1e-9 * exact.max(1.0),
            "case {case}: drift {} vs {}",
            mp.residual_norm_sq(),
            exact
        );
    }
}

/// PROPERTY: the scaled PageRank vector sums to N and is positive for any
/// graph and α ∈ (0,1) (Proposition 1).
#[test]
fn prop_exact_pagerank_properties() {
    for case in 0..25u64 {
        let mut rng = Rng::seeded(9300 + case);
        let g = random_graph(&mut rng);
        let alpha = 0.05 + 0.9 * rng.uniform();
        let x = exact_pagerank(&g, alpha);
        assert!(
            (vector::sum(&x) - g.n() as f64).abs() < 1e-7,
            "case {case}: sum {}",
            vector::sum(&x)
        );
        assert!(x.iter().all(|&v| v > 0.0), "case {case}: nonpositive entry");
    }
}

/// PROPERTY: BColumns sparse ops equal dense B columns on random graphs.
#[test]
fn prop_bcolumns_match_dense() {
    for case in 0..25u64 {
        let mut rng = Rng::seeded(9400 + case);
        let g = random_graph(&mut rng);
        let alpha = 0.1 + 0.85 * rng.uniform();
        let cols = BColumns::new(&g, alpha);
        let b = DenseMatrix::b_matrix(&g, alpha);
        let r: Vec<f64> = (0..g.n()).map(|_| rng.normal()).collect();
        for k in 0..g.n() {
            let want = vector::dot(b.col(k), &r);
            assert!(
                (cols.col_dot(&g, k, &r) - want).abs() < 1e-10,
                "case {case}: col_dot mismatch at {k}"
            );
            assert!(
                (cols.norm_sq(k) - vector::norm2_sq(b.col(k))).abs() < 1e-12,
                "case {case}: norm mismatch at {k}"
            );
        }
    }
}

/// PROPERTY: LU solve then multiply recovers the RHS on random systems.
#[test]
fn prop_lu_roundtrip() {
    for case in 0..25u64 {
        let mut rng = Rng::seeded(9500 + case);
        let n = rng.range(2, 40);
        let vals: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let a = DenseMatrix::from_fn(n, n, |i, j| vals[i * n + j]);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let lu = match Lu::factor(&a) {
            Ok(lu) => lu,
            Err(_) => continue, // singular draw: skip
        };
        let x = lu.solve(&b);
        let ax = a.matvec(&x);
        assert!(
            vector::dist_inf(&ax, &b) < 1e-7,
            "case {case}: residual {}",
            vector::dist_inf(&ax, &b)
        );
    }
}

/// PROPERTY: parallel batches equal any sequential order of the same
/// activations (commutation on disjoint supports).
#[test]
fn prop_parallel_batches_commute() {
    for case in 0..20u64 {
        let mut rng = Rng::seeded(9600 + case);
        let n = rng.range(50, 200);
        let g = generators::erdos_renyi(n, 2.0 / n as f64, 9600 + case);
        let mut pmp = ParallelMatchingPursuit::new(&g, 0.85, 8);
        for _ in 0..10 {
            let batch = pmp.pack_batch(&mut rng);
            if batch.len() < 2 {
                pmp.apply_batch(&batch);
                continue;
            }
            // compare forward and reversed application on clones
            let mut fwd = pmp.clone();
            let mut rev = pmp.clone();
            fwd.apply_batch(&batch);
            let reversed: Vec<usize> = batch.iter().rev().copied().collect();
            rev.apply_batch(&reversed);
            assert!(
                vector::dist_inf(fwd.residual(), rev.residual()) < 1e-13,
                "case {case}: batch application order mattered"
            );
            pmp.apply_batch(&batch);
        }
    }
}

/// PROPERTY: Algorithm 2 conserves Σs = 1 on any strongly connected graph.
#[test]
fn prop_size_estimation_sum_invariant() {
    let mut found = 0;
    for case in 0..40u64 {
        let mut rng = Rng::seeded(9700 + case);
        let g = random_graph(&mut rng);
        let Ok(mut est) = SizeEstimator::new(&g) else {
            continue;
        };
        found += 1;
        for _ in 0..300 {
            est.step(&mut rng);
        }
        let s = vector::sum(est.s());
        assert!((s - 1.0).abs() < 1e-9, "case {case}: sum {s}");
    }
    assert!(found > 10, "too few strongly connected draws ({found})");
}

/// PROPERTY: WeightTree sampling matches a naive linear-scan sampler in
/// distribution, under random weight updates.
#[test]
fn prop_weight_tree_vs_naive() {
    for case in 0..10u64 {
        let mut rng = Rng::seeded(9800 + case);
        let n = rng.range(3, 50);
        let mut weights: Vec<f64> = (0..n).map(|_| rng.uniform() * 10.0).collect();
        let mut tree = WeightTree::new(&weights);
        // random updates
        for _ in 0..20 {
            let i = rng.below(n);
            let w = rng.uniform() * 10.0;
            weights[i] = w;
            tree.update(i, w);
        }
        assert!((tree.total() - weights.iter().sum::<f64>()).abs() < 1e-9);
        // empirical distribution agreement (coarse)
        let draws = 40_000;
        let mut counts = vec![0f64; n];
        for _ in 0..draws {
            counts[tree.sample(&mut rng)] += 1.0;
        }
        let total: f64 = weights.iter().sum();
        for i in 0..n {
            let expect = weights[i] / total;
            let got = counts[i] / draws as f64;
            assert!(
                (got - expect).abs() < 0.025 + 0.2 * expect,
                "case {case}: index {i} got {got} want {expect}"
            );
        }
    }
}

/// PROPERTY: JSON render/parse round-trips random values.
#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Number((rng.normal() * 100.0).round()),
            3 => Json::String(format!("s{}", rng.below(1000))),
            4 => Json::Array((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Object(m)
            }
        }
    }
    for case in 0..200u64 {
        let mut rng = Rng::seeded(9900 + case);
        let v = random_json(&mut rng, 3);
        let text = v.render();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} in {text}"));
        assert_eq!(back, v, "case {case}: round trip changed value");
    }
}

/// PROPERTY: ranking agreement is reflexive and symmetric.
#[test]
fn prop_ranking_agreement_axioms() {
    for case in 0..50u64 {
        let mut rng = Rng::seeded(10_000 + case);
        let n = rng.range(2, 30);
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        assert_eq!(pagerank_mp::util::stats::ranking_agreement(&a, &a), 1.0);
        let ab = pagerank_mp::util::stats::ranking_agreement(&a, &b);
        let ba = pagerank_mp::util::stats::ranking_agreement(&b, &a);
        assert!((ab - ba).abs() < 1e-15, "case {case}: asymmetric");
        assert!((0.0..=1.0).contains(&ab));
    }
}

/// Random edge-list text exercising the SNAP quirks the streaming
/// loader must absorb: header variants, `#`/`%` comments (also in the
/// middle of the file), tab and space separators, blank lines,
/// duplicate edges, self-loops, and pages with no out-links. Returns
/// `(n, edges, text)` where `edges` is the logical edge set the text
/// encodes against a declared node count of `n`.
fn random_edge_list_text(rng: &mut Rng) -> (usize, Vec<(usize, usize)>, String) {
    let n = rng.range(3, 40);
    let m = rng.range(0, 4 * n);
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(m);
    for _ in 0..m {
        edges.push((rng.below(n), rng.below(n)));
    }
    // Duplicate a slice of the edges verbatim — dedup is the loader's job.
    if !edges.is_empty() && rng.bernoulli(0.5) {
        let k = rng.below(edges.len());
        let dup = edges[k];
        edges.push(dup);
    }
    let mut text = String::new();
    match rng.below(3) {
        0 => text.push_str(&format!("# nodes: {n}\n")),
        1 => text.push_str(&format!("# Nodes: {n} Edges: {}\n", edges.len())),
        _ => text.push_str(&format!("# NODES: {n}\n")),
    }
    text.push_str("% matrix-market style comment\n");
    for (i, &(s, d)) in edges.iter().enumerate() {
        if rng.bernoulli(0.1) {
            text.push_str("# interior comment\n");
        }
        if rng.bernoulli(0.1) {
            text.push('\n');
        }
        let sep = if i % 2 == 0 { '\t' } else { ' ' };
        text.push_str(&format!("{s}{sep}{d}\n"));
    }
    (n, edges, text)
}

/// PROPERTY: the streaming two-pass loader and the buffer-everything
/// GraphBuilder are the same function — identical graphs on success and
/// identical first-dangler diagnostics on [`DanglingPolicy::Error`] —
/// across duplicates, self-loops, header variants, and all 3 policies.
#[test]
fn prop_streaming_loader_matches_builder_under_all_policies() {
    let policies = [
        DanglingPolicy::Error,
        DanglingPolicy::SelfLoop,
        DanglingPolicy::LinkAll,
    ];
    for case in 0..30u64 {
        let mut rng = Rng::seeded(10_100 + case);
        let (n, edges, text) = random_edge_list_text(&mut rng);
        for policy in policies {
            let streamed = graph_io::read_edge_list_streaming(
                std::io::Cursor::new(text.as_bytes()),
                &LoadOptions::new(policy),
            );
            let mut b = GraphBuilder::new(n).dangling_policy(policy);
            for &(s, d) in &edges {
                b.add_edge(s, d);
            }
            match (b.build(), streamed) {
                (Ok(want), Ok(got)) => {
                    assert_eq!(got, want, "case {case}: {policy:?} graphs diverge");
                }
                (Err(BuildError::Dangling(want)), Err(IoError::Build(BuildError::Dangling(got)))) => {
                    assert_eq!(got, want, "case {case}: first dangler diverges");
                }
                (want, got) => {
                    panic!("case {case}: {policy:?} outcomes diverge: builder {want:?} vs streaming {got:?}")
                }
            }
        }
    }
}

/// PROPERTY: write_edge_list → read_edge_list reproduces the graph
/// exactly (the header pins `n`, so trailing dangling pages survive).
#[test]
fn prop_save_load_round_trips() {
    for case in 0..30u64 {
        let mut rng = Rng::seeded(10_200 + case);
        let g = random_graph(&mut rng);
        let mut bytes = Vec::new();
        graph_io::write_edge_list(&g, &mut bytes).expect("write to Vec");
        let back = graph_io::read_edge_list(std::io::Cursor::new(bytes), DanglingPolicy::SelfLoop)
            .unwrap_or_else(|e| panic!("case {case}: reload failed: {e:?}"));
        assert_eq!(back, g, "case {case}: text round trip changed the graph");
    }
}

/// PROPERTY: the `.csrbin` binary cache round-trips random graphs
/// bit-exactly and preserves the ingest options.
#[test]
fn prop_csrbin_round_trips_random_graphs() {
    let dir = std::env::temp_dir().join(format!("prmp_propcsrbin_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    for case in 0..30u64 {
        let mut rng = Rng::seeded(10_300 + case);
        let g = random_graph(&mut rng);
        let opts = LoadOptions::new(DanglingPolicy::SelfLoop).remap_ids(case % 2 == 0);
        let path = dir.join(format!("case_{case}.csrbin"));
        graph_io::write_csrbin(&g, &path, &opts).expect("write csrbin");
        let (back, back_opts) = graph_io::read_csrbin(&path)
            .unwrap_or_else(|e| panic!("case {case}: csrbin read failed: {e:?}"));
        assert_eq!(back, g, "case {case}: csrbin round trip changed the graph");
        assert_eq!(back_opts.dangling, opts.dangling, "case {case}: policy lost");
        assert_eq!(back_opts.remap_ids, opts.remap_ids, "case {case}: remap flag lost");
        let _ = std::fs::remove_file(&path);
    }
    let _ = std::fs::remove_dir(&dir);
}

/// PROPERTY: reliable delivery is exactly-once. Under any random fault
/// plan (drop / duplicate / reorder jitter), the sequence-numbered
/// receiver never surfaces one payload twice — a double-applied residual
/// delta would silently break eq. 11 conservation — and, whenever no
/// message exhausted its retry budget, every payload surfaces exactly
/// once despite the wire's losses and duplicates.
#[test]
fn prop_reliable_transport_never_double_delivers() {
    use pagerank_mp::network::{
        FaultPlan, LatencyModel, NetProfile, Transport, TransportEvent, WireSized,
    };

    #[derive(Debug, Clone, PartialEq)]
    struct Packet(u32);
    impl WireSized for Packet {
        fn wire_bytes(&self) -> usize {
            8
        }
    }

    for case in 0..30u64 {
        let mut rng = Rng::seeded(10_500 + case);
        let shards = rng.range(2, 6);
        let plan = FaultPlan::default()
            .with_drop(0.4 * rng.uniform())
            .with_duplicate(0.4 * rng.uniform())
            .with_jitter(4.0 * rng.uniform())
            .with_seed(31_000 + case);
        let latency = LatencyModel::Exponential { mean: 0.5 };
        let mut tp: Transport<Packet> =
            Transport::with_profile(shards, latency, NetProfile::faulty(plan).reliable());
        let sent = rng.range(20, 120);
        let mut net_rng = rng.fork(7);
        for i in 0..sent {
            let src = rng.below(shards);
            let dst = (src + 1 + rng.below(shards - 1)) % shards;
            tp.send(src, dst, Packet(i as u32), &mut net_rng);
        }
        let mut surfaced = vec![0u32; sent];
        while let Some(ev) = tp.pop() {
            if let TransportEvent::Deliver { msg, .. } = ev.event {
                surfaced[msg.0 as usize] += 1;
            }
        }
        for (i, &count) in surfaced.iter().enumerate() {
            assert!(
                count <= 1,
                "case {case}: payload {i} surfaced {count} times — seq dedup double-applied"
            );
        }
        if tp.abandoned() == 0 {
            let delivered: u32 = surfaced.iter().sum();
            assert_eq!(
                delivered, sent as u32,
                "case {case}: no message gave up, so every payload must surface exactly once"
            );
        }
    }
}

/// PROPERTY: conservation after heal (eq. 11 under partition
/// tolerance). Under a composed fault plan — an asymmetric link window,
/// a healing shard bipartition and two *overlapping* crash windows,
/// optionally with 5% random drop — the reliable msgpass backend drains
/// back to exact conservation once every window heals: retransmission
/// replays what the cuts swallowed, the restart/heal re-syncs repair the
/// replicas, and no frame ever exhausts its RTT-denominated retry
/// budget.
#[test]
fn prop_reliable_msgpass_conserves_after_heal() {
    use pagerank_mp::coordinator::{MsgpassConfig, MsgpassRuntime, ShardMap};
    use pagerank_mp::network::{CrashWindow, FaultPlan, LatencyModel, LinkWindow, PartitionWindow};

    for case in 0..10u64 {
        let mut rng = Rng::seeded(10_600 + case);
        let n = rng.range(16, 40);
        let g = generators::er_threshold(n, 0.5, 10_600 + case);
        let shards = rng.range(3, 6);
        let at = 20.0 + 30.0 * rng.uniform();
        let down = 8.0 + 16.0 * rng.uniform();
        let src = rng.below(shards);
        let dst = (src + 1 + rng.below(shards - 1)) % shards;
        let crash_a = rng.below(shards);
        let crash_b = (crash_a + 1) % shards;
        let plan = FaultPlan::default()
            .with_seed(31_600 + case)
            .with_drop(if rng.bernoulli(0.5) { 0.05 } else { 0.0 })
            .with_link(LinkWindow { src, dst, at, down_for: down })
            .with_partition(PartitionWindow::new(vec![rng.below(shards)], at + 5.0, down))
            // down_for >= 8, so the second window opens before the first
            // closes: the overlapping-crash case the single-crash era
            // rejected.
            .with_crash(CrashWindow { shard: crash_a, at: at + 10.0, down_for: down })
            .with_crash(CrashWindow { shard: crash_b, at: at + 14.0, down_for: down });
        let cfg = MsgpassConfig::new(shards, 2 * shards, ShardMap::Modulo, 4, LatencyModel::Zero)
            .with_faults(plan)
            .reliable();
        let mut rt = MsgpassRuntime::with_config(g.clone(), 0.85, cfg);
        let mut run_rng = rng.fork(1);
        for _ in 0..400 {
            rt.try_run_super_step(&mut run_rng)
                .expect("reliable faulted run must drain every super-step");
        }
        let f = rt.fault_counters();
        assert_eq!(
            rt.abandoned_messages(),
            0,
            "case {case}: an outage must never exhaust the RTT-denominated retry budget"
        );
        assert_eq!(f.recoveries, 2, "case {case}: both overlapping crashes must restart");
        assert_eq!(f.partitions_healed, 1, "case {case}: the bipartition must heal");
        let b = DenseMatrix::b_matrix(&g, 0.85);
        let bx = b.matvec(&rt.estimate());
        let viol = bx
            .iter()
            .zip(&rt.residual())
            .map(|(v, r)| (v + r - 0.15).abs())
            .fold(0.0, f64::max);
        assert!(viol < 1e-9, "case {case}: conservation violated by {viol:.3e} after heal");
    }
}

/// PROPERTY: the raw wire under a healing bipartition is honestly
/// degraded — the ledger counts every frame the cut swallowed, the
/// divergence gauge is sampled at partition onset and heal, and the
/// owner-bound deltas the cut dropped leave a nonzero conservation
/// violation that raw mode (no retransmission) can never repair.
#[test]
fn prop_raw_msgpass_counts_partition_losses_honestly() {
    use pagerank_mp::coordinator::{MsgpassConfig, MsgpassRuntime, ShardMap};
    use pagerank_mp::network::{FaultPlan, LatencyModel, PartitionWindow};

    let cases = 10u64;
    let mut violated = 0usize;
    let mut gauged = 0usize;
    for case in 0..cases {
        let mut rng = Rng::seeded(10_700 + case);
        let n = rng.range(16, 40);
        let g = generators::er_threshold(n, 0.5, 10_700 + case);
        let shards = rng.range(2, 5);
        let plan = FaultPlan::default()
            .with_seed(31_700 + case)
            .with_partition(PartitionWindow::new(vec![rng.below(shards)], 30.0, 20.0));
        let cfg = MsgpassConfig::new(shards, 2 * shards, ShardMap::Modulo, 4, LatencyModel::Zero)
            .with_faults(plan);
        let mut rt = MsgpassRuntime::with_config(g.clone(), 0.85, cfg);
        let mut run_rng = rng.fork(1);
        for _ in 0..300 {
            rt.try_run_super_step(&mut run_rng)
                .expect("raw faulted run must drain every super-step");
        }
        let f = rt.fault_counters();
        assert!(
            f.link_downs > 0,
            "case {case}: a 20-vtime all-link cut on a dense graph must swallow traffic"
        );
        assert_eq!(f.retransmits, 0, "case {case}: raw mode never retransmits");
        assert_eq!(f.partitions_healed, 1, "case {case}: the window must heal");
        let (onset, heal) = rt.partition_divergence();
        assert!(onset.is_finite() && onset >= 0.0, "case {case}: onset gauge {onset}");
        assert!(heal.is_finite() && heal >= 0.0, "case {case}: heal gauge {heal}");
        if heal > 0.0 {
            gauged += 1;
        }
        let b = DenseMatrix::b_matrix(&g, 0.85);
        let bx = b.matvec(&rt.estimate());
        let viol = bx
            .iter()
            .zip(&rt.residual())
            .map(|(v, r)| (v + r - 0.15).abs())
            .fold(0.0, f64::max);
        if viol > 1e-9 {
            violated += 1;
        }
    }
    // Every case is seeded (replayable), but whether a specific run loses
    // an owner-bound delta inside its window is plan-dependent — demand a
    // solid majority rather than pinning each seed.
    assert!(
        violated >= cases as usize / 2,
        "only {violated}/{cases} raw runs showed the expected conservation debt"
    );
    assert!(
        gauged >= cases as usize / 2,
        "only {gauged}/{cases} raw runs gauged heal-time divergence"
    );
}

/// PROPERTY: `remap_ids` compacts sparse/gappy ids to first-seen order —
/// the same graph as manually renumbering ids in line order (src before
/// dst) and feeding the builder.
#[test]
fn prop_remap_matches_first_seen_compaction() {
    for case in 0..30u64 {
        let mut rng = Rng::seeded(10_400 + case);
        let n = rng.range(3, 30);
        let offset = rng.below(1_000_000);
        let stride = 1 + rng.below(997);
        let m = rng.range(1, 4 * n);
        let sparse: Vec<(usize, usize)> = (0..m)
            .map(|_| (offset + stride * rng.below(n), offset + stride * rng.below(n)))
            .collect();
        let mut text = String::new();
        for &(s, d) in &sparse {
            text.push_str(&format!("{s} {d}\n"));
        }
        let streamed = graph_io::read_edge_list_streaming(
            std::io::Cursor::new(text.as_bytes()),
            &LoadOptions::new(DanglingPolicy::SelfLoop).remap_ids(true),
        )
        .unwrap_or_else(|e| panic!("case {case}: remap load failed: {e:?}"));
        // Emulate pass 1's first-seen numbering: src then dst, line order.
        let mut seen: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
        let mut compact: Vec<(usize, usize)> = Vec::with_capacity(sparse.len());
        for &(s, d) in &sparse {
            let next = seen.len();
            let cs = *seen.entry(s).or_insert(next);
            let next = seen.len();
            let cd = *seen.entry(d).or_insert(next);
            compact.push((cs, cd));
        }
        let mut b = GraphBuilder::new(seen.len()).dangling_policy(DanglingPolicy::SelfLoop);
        for (s, d) in compact {
            b.add_edge(s, d);
        }
        let want = b.build().expect("compacted graph builds");
        assert_eq!(streamed, want, "case {case}: remap diverges from first-seen compaction");
    }
}
