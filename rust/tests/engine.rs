//! Engine-level integration tests: the solver registry, scenario JSON
//! round-trips, determinism, backend equivalences (matrix vs
//! coordinator vs sharded vs dense), dangling-node safety, and the
//! sweep grid through the declarative API.

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::coordinator::{Packer, Sampling, ShardMap};
use pagerank_mp::engine::{
    CoordinatorSolver, EstimatorSpec, GraphSpec, ReferencePolicy, Scenario, ScenarioReport,
    ShardedSolver, SolverSpec, Sweep,
};
use pagerank_mp::graph::generators;
use pagerank_mp::harness::fig2;
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::util::json::Json;
use pagerank_mp::util::rng::Rng;

fn small(name: &str, solvers: Vec<SolverSpec>) -> Scenario {
    Scenario::paper(name, 25)
        .with_solvers(solvers)
        .with_steps(800)
        .with_stride(100)
        .with_rounds(3)
        .with_threads(2)
        .with_seed(41)
}

#[test]
fn registry_round_trips_every_solver_name() {
    let all = SolverSpec::all();
    assert!(all.len() >= 10, "the registry must cover the 10+ variants");
    for spec in &all {
        let key = spec.key();
        let back = SolverSpec::parse(&key)
            .unwrap_or_else(|e| panic!("canonical key {key:?} failed to parse: {e}"));
        assert_eq!(&back, spec, "{key} did not round-trip");
    }
    // Baselines are a subset of the registry.
    for spec in SolverSpec::all_baselines() {
        assert!(SolverSpec::parse(&spec.key()).is_ok());
    }
}

#[test]
fn scenario_json_serialize_deserialize_run_is_deterministic() {
    let scenario = small("det", vec![SolverSpec::Mp, SolverSpec::LeiChen]);
    let text = scenario.to_json().render();
    let reparsed = Scenario::from_json_str(&text).expect("scenario JSON round-trips");
    assert_eq!(reparsed, scenario);

    let a = scenario.run().expect("original runs");
    let b = reparsed.run().expect("reparsed runs");
    assert_eq!(a.solver_reports().len(), b.solver_reports().len());
    for (ra, rb) in a.solver_reports().iter().zip(b.solver_reports()) {
        assert_eq!(ra.spec, rb.spec);
        // Same seed ⇒ identical mean trajectory, bit for bit.
        assert_eq!(ra.trajectory.mean, rb.trajectory.mean);
        assert_eq!(ra.trajectory.variance, rb.trajectory.variance);
        assert_eq!(ra.total_stats, rb.total_stats);
    }
}

#[test]
fn zero_latency_coordinator_matches_matrix_mp_bit_for_bit() {
    // The sequential zero-latency coordinator and the matrix-form MP are
    // the same algorithm realized at two layers; through the Scenario
    // seed protocol they replay identical activation sequences and the
    // recorded trajectories must agree exactly.
    let scenario = small(
        "coord-vs-mp",
        vec![SolverSpec::Mp, SolverSpec::sequential_coordinator()],
    );
    let report = scenario.run().expect("runs");
    let mp = report.get("mp").expect("mp ran");
    let coord = report
        .get("coordinator:sequential:uniform:zero")
        .expect("coordinator ran");
    assert_eq!(
        mp.trajectory.mean, coord.trajectory.mean,
        "distributed and matrix forms must be bit-identical under an ideal network"
    );
    assert_eq!(mp.trajectory.variance, coord.trajectory.variance);
    // Same activation sequence ⇒ same logical read counts (no self-loops
    // in the ER-threshold model, so wire writes match too).
    assert_eq!(mp.total_stats.reads, coord.total_stats.reads);
    assert_eq!(mp.total_stats.writes, coord.total_stats.writes);
}

#[test]
fn reference_policies_agree() {
    let exact = small("ref-exact", vec![SolverSpec::Mp]);
    let power = exact
        .clone()
        .with_reference(ReferencePolicy::Power { tol: 1e-14 });
    let a = exact.run().expect("exact runs");
    let b = power.run().expect("power runs");
    // Same solver stream, near-identical reference ⇒ near-identical
    // trajectories.
    for (ea, eb) in a.solver_reports()[0]
        .trajectory
        .mean
        .iter()
        .zip(&b.solver_reports()[0].trajectory.mean)
    {
        assert!((ea - eb).abs() < 1e-9, "{ea} vs {eb}");
    }
}

#[test]
fn every_registry_solver_runs_inside_a_scenario() {
    let scenario = Scenario::paper("all-solvers", 12)
        .with_solvers(SolverSpec::all())
        .with_steps(120)
        .with_stride(40)
        .with_rounds(2)
        .with_threads(2)
        .with_seed(9);
    let report = scenario.run().expect("every registered solver must run");
    assert_eq!(report.solver_reports().len(), SolverSpec::all().len());
    for r in report.solver_reports() {
        assert_eq!(r.trajectory.mean.len(), 4, "{}: t = 0,40,80,120", r.spec.key());
        assert!(
            r.trajectory.mean.iter().all(|v| v.is_finite()),
            "{}: non-finite trajectory",
            r.spec.key()
        );
        assert!(r.total_stats.activated > 0, "{}: nothing activated", r.spec.key());
    }
}

#[test]
fn shipped_fig1_scenario_file_parses_and_names_the_paper_setup() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package sits inside the repo")
        .join("examples/fig1_scenario.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let scenario = Scenario::from_json_str(&text).expect("shipped scenario parses");
    assert_eq!(scenario.graph, GraphSpec::ErThreshold { n: 100, threshold: 0.5 });
    assert_eq!(scenario.alpha, 0.85);
    for required in ["mp", "ishii-tempo", "lei-chen"] {
        assert!(
            scenario.solvers().iter().any(|s| s.key() == required),
            "fig1 scenario must include {required}"
        );
    }
}

#[test]
fn fig1_ordering_reproduced_at_reduced_scale() {
    // The acceptance ordering of the full `run-scenario
    // examples/fig1_scenario.json` run, pinned here at test scale: MP's
    // fitted decay rate is strictly better (smaller) than Ishii–Tempo's
    // and Lei–Chen's.
    let scenario = Scenario::paper("fig1-ordering", 30)
        .with_solvers(vec![
            SolverSpec::Mp,
            SolverSpec::IshiiTempo,
            SolverSpec::LeiChen,
        ])
        .with_steps(9_000)
        .with_stride(300)
        .with_rounds(6)
        .with_threads(4)
        .with_seed(2017);
    let report = scenario.run().expect("runs");
    let mp = report.get("mp").expect("mp").decay_rate;
    let it = report.get("ishii-tempo").expect("it").decay_rate;
    let lc = report.get("lei-chen").expect("lc").decay_rate;
    assert!(mp < it, "MP ({mp}) must out-decay Ishii–Tempo ({it})");
    assert!(mp < lc, "MP ({mp}) must out-decay Lei–Chen ({lc})");
    assert_eq!(report.rate_ordering()[0].0, "mp");
}

/// The perf-trajectory artifact: BENCH_scenario.json carries per-solver
/// final error, decay rate, communication counts and wall time.
#[test]
fn bench_json_is_machine_readable() {
    let report: ScenarioReport = small("bench-dump", vec![SolverSpec::Mp])
        .run()
        .expect("runs");
    let dir = std::env::temp_dir().join(format!("prmp_engine_{}", std::process::id()));
    let path = dir.join("BENCH_scenario.json");
    report.write_bench_json(&path).expect("writes");
    let parsed = Json::parse(&std::fs::read_to_string(&path).expect("readable"))
        .expect("valid JSON on disk");
    let solvers = parsed.get("solvers").and_then(Json::as_array).expect("solvers array");
    assert_eq!(solvers.len(), 1);
    for field in ["name", "final_error", "decay_rate", "reads", "writes", "wall_ms"] {
        assert!(
            solvers[0].get(field).is_some(),
            "BENCH_scenario.json solver entry missing {field:?}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn async_coordinator_scenario_keeps_overlap_and_converges() {
    // Async + latency through the declarative API: recording happens in
    // stride-sized chunks, so activations overlap within a chunk instead
    // of being quiesced one by one.
    let spec = SolverSpec::parse("coordinator:async:clocks:const:0.2").expect("parses");
    let scenario = Scenario::paper("async-coord", 40)
        .with_solvers(vec![spec])
        .with_steps(600)
        .with_stride(200)
        .with_rounds(2)
        .with_threads(1)
        .with_seed(17);
    let report = scenario.run().expect("runs");
    let r = &report.solver_reports()[0];
    assert_eq!(r.trajectory.mean.len(), 4); // t = 0,200,400,600
    assert!(
        r.final_error < r.trajectory.mean[0],
        "async coordinator must make progress"
    );
    // Each round completes at least its budget (drain may add a few).
    assert!(r.total_stats.activated >= 2 * 600);
}

#[test]
fn one_shard_sharded_scenario_matches_matrix_mp() {
    // Backend equivalence anchor, pinned for BOTH packers: shards=1,
    // batch=1 packs exactly one uniform candidate per super-step from
    // the same Scenario rng stream as the matrix form (the worker packer
    // clones that stream into worker 0), and the shared BColumns
    // arithmetic makes all three backends replay identical activation
    // sequences.
    let report = small(
        "sharded-vs-mp",
        vec![
            SolverSpec::Mp,
            SolverSpec::parse("sharded:1:1").expect("registry"),
            SolverSpec::parse("sharded:1:1:mod:worker").expect("registry"),
        ],
    )
    .run()
    .expect("runs");
    let mp = report.get("mp").expect("mp ran");
    for key in ["sharded:1:1:mod:leader", "sharded:1:1:mod:worker"] {
        let sh = report.get(key).expect("sharded ran");
        assert_eq!(
            mp.total_stats, sh.total_stats,
            "{key}: identical activation sequences must cost the same"
        );
        for (a, b) in mp.trajectory.mean.iter().zip(&sh.trajectory.mean) {
            assert!(
                (a - b).abs() <= 1e-9 * a.abs() + 1e-30,
                "{key}: trajectories diverged: {a} vs {b}"
            );
        }
        assert_eq!(sh.conflicts, 0, "{key}: a single candidate can never conflict");
    }
}

#[test]
fn one_shard_msgpass_scenario_matches_matrix_mp() {
    // The message-passing equivalence anchor: msgpass:1:1:mod at zero
    // latency runs one activation per super-step on a single shard whose
    // candidate stream is a verbatim clone of the Scenario rng (the same
    // protocol as the sharded worker packer), and one shard never sends
    // a message — so the trajectory must replay `mp` bit for bit.
    let report = small(
        "msgpass-vs-mp",
        vec![
            SolverSpec::Mp,
            SolverSpec::parse("msgpass:1:1:mod").expect("registry"),
        ],
    )
    .run()
    .expect("runs");
    let mp = report.get("mp").expect("mp ran");
    let msg = report.get("msgpass:1:1:mod").expect("msgpass ran");
    assert_eq!(
        mp.total_stats, msg.total_stats,
        "identical activation sequences must cost the same"
    );
    for (a, b) in mp.trajectory.mean.iter().zip(&msg.trajectory.mean) {
        assert!(
            (a - b).abs() <= 1e-9 * a.abs() + 1e-30,
            "trajectories diverged: {a} vs {b}"
        );
    }
    assert_eq!(msg.conflicts, 0, "msgpass owners never conflict");
}

#[test]
fn one_shard_table_backed_maps_match_matrix_mp() {
    // Equivalence anchor for the table-backed maps: at shards=1 every
    // map (closed-form or partitioned) owns all pages in ascending
    // order, so cluster/scc-mapped runs must replay `mp` exactly —
    // a partition changes where pages live, never the arithmetic.
    let report = small(
        "table-maps-vs-mp",
        vec![
            SolverSpec::Mp,
            SolverSpec::parse("sharded:1:1:cluster:worker").expect("registry"),
            SolverSpec::parse("sharded:1:1:scc:leader").expect("registry"),
            SolverSpec::parse("msgpass:1:1:cluster").expect("registry"),
        ],
    )
    .run()
    .expect("runs");
    let mp = report.get("mp").expect("mp ran");
    for key in [
        "sharded:1:1:cluster:worker",
        "sharded:1:1:scc:leader",
        "msgpass:1:1:cluster",
    ] {
        let r = report.get(key).expect("table-backed run");
        assert_eq!(
            mp.total_stats, r.total_stats,
            "{key}: identical activation sequences must cost the same"
        );
        for (a, b) in mp.trajectory.mean.iter().zip(&r.trajectory.mean) {
            assert!(
                (a - b).abs() <= 1e-9 * a.abs() + 1e-30,
                "{key}: trajectories diverged: {a} vs {b}"
            );
        }
        assert!(
            !r.locality.any(),
            "{key}: one shard has no boundary to cross"
        );
    }
}

#[test]
fn every_shard_map_reaches_the_exact_fixed_point() {
    // ER (homogeneous), BA (hub-heavy), chain (multi-SCC with a genuine
    // sink): all four shard maps must converge to the same
    // exact_pagerank fixed point — a partition can cost locality, never
    // correctness.
    for (family, g, steps) in [
        ("er", generators::erdos_renyi(60, 0.1, 51), 25_000usize),
        ("ba", generators::barabasi_albert(60, 4, 52), 25_000),
        ("chain", generators::chain(30), 40_000),
    ] {
        let x_star = exact_pagerank(&g, 0.85);
        for map in [ShardMap::Modulo, ShardMap::Block, ShardMap::Cluster, ShardMap::Scc] {
            let mut sh =
                ShardedSolver::new(&g, 0.85, 3, 8, map, Packer::Worker, Sampling::Uniform);
            let mut rng = Rng::seeded(53);
            for _ in 0..steps {
                sh.step(&mut rng);
            }
            let err = sh.error_sq_vs(&x_star);
            assert!(err < 1e-10, "{family}/{map:?}: ‖x-x*‖² = {err}");
        }
    }
}

#[test]
fn one_shard_residual_sharded_matches_matrix_residual_mp() {
    // The residual-sampling equivalence anchor, pinned for BOTH packers:
    // at shards=1 batch=1, the global and per-shard weight trees are the
    // same tree over the same stream (worker 0 clones the Scenario rng),
    // weight refreshes walk the same ascending-page order, and the
    // BColumns arithmetic is shared — so both sharded residual policies
    // replay `mp:residual` exactly.
    let report = small(
        "sharded-residual-vs-mp",
        vec![
            SolverSpec::parse("mp:residual").expect("registry"),
            SolverSpec::parse("sharded:1:1:mod:leader:residual").expect("registry"),
            SolverSpec::parse("sharded:1:1:mod:worker:residual").expect("registry"),
        ],
    )
    .run()
    .expect("runs");
    let rmp = report.get("mp:residual").expect("mp:residual ran");
    for key in [
        "sharded:1:1:mod:leader:residual",
        "sharded:1:1:mod:worker:residual",
    ] {
        let sh = report.get(key).expect("sharded residual ran");
        assert_eq!(
            rmp.total_stats, sh.total_stats,
            "{key}: identical activation sequences must cost the same"
        );
        for (a, b) in rmp.trajectory.mean.iter().zip(&sh.trajectory.mean) {
            assert!(
                (a - b).abs() <= 1e-9 * a.abs() + 1e-30,
                "{key}: trajectories diverged: {a} vs {b}"
            );
        }
        assert_eq!(sh.conflicts, 0, "{key}: a single candidate can never conflict");
    }
}

#[test]
fn residual_mp_reaches_the_exact_fixed_point_on_every_family() {
    // ER (homogeneous), BA (hub-heavy — where importance sampling pays),
    // chain (genuine dangling sink): the floored residual weighting must
    // converge to the same exact_pagerank fixed point as uniform mp.
    for (family, g, steps) in [
        ("er", generators::erdos_renyi(60, 0.1, 81), 180_000usize),
        ("ba", generators::barabasi_albert(60, 4, 82), 180_000),
        ("chain", generators::chain(30), 90_000),
    ] {
        let x_star = exact_pagerank(&g, 0.85);
        let spec = SolverSpec::parse("mp:residual").expect("registry");
        let mut solver = spec.build(&g, 0.85, 83);
        let mut rng = Rng::seeded(84);
        for _ in 0..steps {
            solver.step(&mut rng);
        }
        let err = solver.error_sq_vs(&x_star);
        assert!(err < 1e-10, "{family}: ‖x-x*‖² = {err}");
    }
}

#[test]
fn residual_sharded_converges_and_races_in_a_scenario() {
    // The multi-shard residual policy inside the declarative API: it
    // must converge, count conflicts on a dense graph, and report the
    // same deterministic totals across thread counts.
    let scenario = small(
        "sharded-residual",
        vec![SolverSpec::parse("sharded:2:8:mod:worker:residual").expect("registry")],
    );
    let a = scenario.run().expect("runs");
    let b = scenario.clone().with_threads(1).run().expect("runs");
    let (ra, rb) = (&a.solver_reports()[0], &b.solver_reports()[0]);
    assert!(ra.final_error < ra.trajectory.mean[0], "no progress");
    assert!(ra.conflicts > 0, "dense paper graph must drop candidates");
    assert_eq!(ra.trajectory.mean, rb.trajectory.mean, "thread-count invariance");
    assert_eq!(ra.total_stats, rb.total_stats);
    assert_eq!(ra.conflicts, rb.conflicts);
}

#[test]
fn both_packers_reach_the_exact_fixed_point_on_every_family() {
    // ER (homogeneous), BA (hub-heavy), chain (genuine dangling sink):
    // leader- and worker-packed runs must both converge to the same
    // exact_pagerank fixed point, count their dropped candidates, and
    // keep the §II-D read/write parity.
    for (family, g, steps) in [
        ("er", generators::erdos_renyi(120, 0.05, 71), 30_000usize),
        ("ba", generators::barabasi_albert(120, 4, 72), 30_000),
        ("chain", generators::chain(40), 50_000),
    ] {
        let x_star = exact_pagerank(&g, 0.85);
        for packer in [Packer::Leader, Packer::Worker] {
            let mut sh =
                ShardedSolver::new(&g, 0.85, 3, 8, ShardMap::Modulo, packer, Sampling::Uniform);
            let mut rng = Rng::seeded(73);
            let (mut reads, mut writes) = (0usize, 0usize);
            for _ in 0..steps {
                let st = sh.step(&mut rng);
                reads += st.reads;
                writes += st.writes;
            }
            let err = sh.error_sq_vs(&x_star);
            assert!(err < 1e-10, "{family}/{packer:?}: ‖x-x*‖² = {err}");
            assert_eq!(reads, writes, "{family}/{packer:?}: §II-D parity broken");
            assert!(
                sh.conflicts() > 0,
                "{family}/{packer:?}: batched candidates on a connected graph must collide"
            );
        }
    }
}

#[test]
fn packer_counters_are_deterministic_in_the_seed() {
    // Same seed, same packer => bit-identical estimate and identical
    // activation/read/write/conflict totals, for both packing policies
    // (the worker packer's priority claims are timing-invariant).
    let g = generators::er_threshold(60, 0.4, 74);
    for packer in [Packer::Leader, Packer::Worker] {
        let run = || {
            let mut sh =
                ShardedSolver::new(&g, 0.85, 4, 16, ShardMap::Modulo, packer, Sampling::Uniform);
            let mut rng = Rng::seeded(75);
            let mut activated = 0usize;
            for _ in 0..2_000 {
                activated += sh.step(&mut rng).activated;
            }
            let rt = sh.runtime();
            (
                sh.estimate(),
                activated as u64,
                rt.conflicts(),
                rt.logical_reads(),
                rt.logical_writes(),
            )
        };
        let (xa, aa, ca, ra, wa) = run();
        let (xb, ab, cb, rb, wb) = run();
        assert_eq!(xa, xb, "{packer:?}: estimates must be bit-identical");
        assert_eq!(aa, ab, "{packer:?}: activations");
        assert_eq!(ca, cb, "{packer:?}: conflicts");
        assert_eq!((ra, wa), (rb, wb), "{packer:?}: logical traffic");
        assert_eq!(ra, wa, "{packer:?}: reads must pair with writes");
        assert!(ra >= aa, "{packer:?}: dense pages read at least once per activation");
        assert!(ca > 0, "{packer:?}: the dense paper graph must conflict at budget 16");
    }
}

#[test]
fn dense_backend_matches_power_iteration() {
    // Same Jacobi iteration on two substrates (dense matvec vs CSR
    // scatter): sweep-for-sweep the trajectories must agree to fp noise,
    // far below the 1e-10 acceptance bar.
    let report = Scenario::paper("dense-vs-power", 25)
        .with_solvers(vec![SolverSpec::Dense, SolverSpec::PowerIteration])
        .with_steps(60)
        .with_stride(20)
        .with_rounds(1)
        .with_threads(1)
        .with_seed(13)
        .run()
        .expect("runs");
    let dense = report.get("dense").expect("dense ran");
    let power = report.get("power").expect("power ran");
    for (a, b) in dense.trajectory.mean.iter().zip(&power.trajectory.mean) {
        assert!((a - b).abs() < 1e-10, "dense {a} vs power {b}");
    }
}

#[test]
fn three_backend_race_completes_and_ranks_all() {
    // The acceptance shape of examples/smoke_scenario.json at test
    // scale: one scenario racing the sequential matrix form, the
    // 4-shard runtime and the dense backend, producing one report that
    // ranks all three.
    let report = Scenario::paper("three-backends", 20)
        .with_solvers(vec![
            SolverSpec::Mp,
            SolverSpec::parse("sharded:4:8").expect("registry"),
            SolverSpec::Dense,
        ])
        .with_steps(300)
        .with_stride(100)
        .with_rounds(2)
        .with_threads(1)
        .with_seed(19)
        .run()
        .expect("runs");
    assert_eq!(report.solver_reports().len(), 3);
    for r in report.solver_reports() {
        assert!(r.trajectory.mean.iter().all(|v| v.is_finite()), "{}", r.spec.key());
        assert!(r.final_error < r.trajectory.mean[0], "{}", r.spec.key());
    }
    let ordering = report.rate_ordering();
    assert_eq!(ordering.len(), 3, "every backend appears in the ranking");
    // The dense backend sweeps the whole graph per step: it must lead.
    assert_eq!(ordering[0].0, "dense");
}

#[test]
fn dangling_graph_runs_every_backend_to_finite_convergence() {
    // The chain family keeps a genuine sink page; the shared implicit
    // self-loop guard must carry every backend through it with finite,
    // shrinking errors (regression for the α/0 residual poisoning).
    let scenario = Scenario::new(
        "dangling-chain",
        GraphSpec::Family { family: "chain".into(), n: 20 },
    )
    .with_solvers(vec![
        SolverSpec::Mp,
        SolverSpec::GreedyMp,
        SolverSpec::ParallelMp { batch: 4 },
        SolverSpec::parse("sharded:2:4").expect("registry"),
        SolverSpec::parse("msgpass:2:4:mod").expect("registry"),
        SolverSpec::Dense,
        SolverSpec::PowerIteration,
        // The PR-6 guard extensions: in-link baselines and the
        // random-walk estimator on a genuine sink graph.
        SolverSpec::IshiiTempo,
        SolverSpec::YouTempoQiu,
        SolverSpec::LeiChen,
        SolverSpec::MonteCarlo,
    ])
    .with_steps(2_000)
    .with_stride(500)
    .with_rounds(2)
    .with_threads(2)
    .with_seed(29);
    let report = scenario.run().expect("dangling graph must run");
    for r in report.solver_reports() {
        assert!(
            r.trajectory.mean.iter().all(|v| v.is_finite()),
            "{}: trajectory poisoned by the dangling page",
            r.spec.key()
        );
        assert!(
            r.final_error < r.trajectory.mean[0],
            "{}: no progress on the dangling graph ({} -> {})",
            r.spec.key(),
            r.trajectory.mean[0],
            r.final_error
        );
    }
}

#[test]
fn sweep_expands_grid_and_merges_bench_json() {
    let text = r#"{
      "name": "it-sweep",
      "scenario": {
        "graph": "paper:12",
        "solvers": ["mp", "sharded:2:4"],
        "steps": 200, "stride": 100, "rounds": 2, "threads": 1, "seed": 5
      },
      "grid": {"n": [10, 12], "shards": [1, 2]}
    }"#;
    let sweep = Sweep::from_json_str(text).expect("sweep parses");
    assert_eq!(sweep.cell_count(), 4);
    let report = sweep.run().expect("sweep runs");
    assert_eq!(report.cells.len(), 4);

    let dir = std::env::temp_dir().join(format!("prmp_sweep_{}", std::process::id()));
    let path = dir.join("BENCH_sweep.json");
    report.write_bench_json(&path).expect("writes");
    let parsed = Json::parse(&std::fs::read_to_string(&path).expect("readable"))
        .expect("valid JSON on disk");
    assert_eq!(parsed.get("sweep").and_then(Json::as_str), Some("it-sweep"));
    let cells = parsed.get("cells").and_then(Json::as_array).expect("cells");
    assert_eq!(cells.len(), 4);
    for cell in cells {
        let solvers = cell.get("solvers").and_then(Json::as_array).expect("solvers");
        assert_eq!(solvers.len(), 2, "every cell carries every solver");
        for s in solvers {
            assert!(s.get("final_error").and_then(Json::as_f64).is_some());
            assert!(s.get("conflicts").is_some());
            assert!(s.get("wall_ms").is_some());
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shipped_sweep_and_smoke_files_parse() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package sits inside the repo")
        .to_path_buf();
    let smoke = std::fs::read_to_string(root.join("examples/smoke_scenario.json"))
        .expect("smoke scenario readable");
    let scenario = Scenario::from_json_str(&smoke).expect("smoke scenario parses");
    for required in ["mp", "dense"] {
        assert!(
            scenario.solvers().iter().any(|s| s.key() == required),
            "smoke scenario must race {required}"
        );
    }
    assert!(
        scenario
            .solvers()
            .iter()
            .any(|s| matches!(s, SolverSpec::Sharded { .. })),
        "smoke scenario must include a sharded backend"
    );
    assert!(
        scenario
            .solvers()
            .iter()
            .any(|s| matches!(s, SolverSpec::Msgpass { .. })),
        "smoke scenario must include a msgpass backend"
    );

    let sweep_text = std::fs::read_to_string(root.join("examples/sweep_small.json"))
        .expect("sweep example readable");
    let sweep = Sweep::from_json_str(&sweep_text).expect("sweep example parses");
    assert!(sweep.cell_count() >= 4, "the shipped sweep must be a real >=2x2 grid");
    sweep.cells().expect("every cell must be expandable");

    // The fault-smoke sweep CI runs: drop/crash axes over raw and
    // reliable msgpass on the chain and the paper family.
    let faults_text = std::fs::read_to_string(root.join("examples/faults_sweep.json"))
        .expect("faults sweep readable");
    let faults = Sweep::from_json_str(&faults_text).expect("faults sweep parses");
    assert!(faults.cell_count() >= 4, "graph × crash must be a real grid");
    let cells = faults.cells().expect("every fault cell must be expandable");
    assert!(
        cells.iter().any(|(_, s)| s
            .solvers()
            .iter()
            .any(|sp| matches!(sp, SolverSpec::Msgpass { drop, crashes, reliable: true, .. } if *drop > 0.0 && !crashes.is_empty()))),
        "the fault sweep must exercise drop+crash in reliable mode"
    );
    assert!(
        cells.iter().any(|(_, s)| s
            .solvers()
            .iter()
            .any(|sp| matches!(sp, SolverSpec::Msgpass { reliable: false, drop, .. } if *drop > 0.0))),
        "the fault sweep must race the raw wire under the same plan"
    );

    // The partition-smoke sweep CI runs: link/partition axes over raw
    // and reliable msgpass.
    let parts_text = std::fs::read_to_string(root.join("examples/partitions_sweep.json"))
        .expect("partitions sweep readable");
    let parts = Sweep::from_json_str(&parts_text).expect("partitions sweep parses");
    assert!(parts.cell_count() >= 4, "link × partition must be a real grid");
    let cells = parts.cells().expect("every partition cell must be expandable");
    assert!(
        cells.iter().any(|(_, s)| s.solvers().iter().any(|sp| matches!(
            sp,
            SolverSpec::Msgpass { links, partitions, reliable: true, .. }
                if !links.is_empty() && !partitions.is_empty()
        ))),
        "the partition sweep must exercise link+partition windows in reliable mode"
    );
    assert!(
        cells.iter().any(|(_, s)| s.solvers().iter().any(|sp| matches!(
            sp,
            SolverSpec::Msgpass { links, reliable: false, .. } if !links.is_empty()
        ))),
        "the partition sweep must race the raw wire under the same windows"
    );
    assert!(
        cells.iter().any(|(_, s)| s.solvers().iter().any(|sp| matches!(
            sp,
            SolverSpec::Msgpass { links, partitions, .. }
                if links.is_empty() && partitions.is_empty()
        ))),
        "the partition sweep must keep a window-free control cell"
    );
}

#[test]
fn faulted_msgpass_scenarios_thread_the_fault_ledger_into_reports() {
    // End-to-end through the engine: a drop+crash plan parsed from the
    // registry string, run by a Scenario, lands its fault ledger on the
    // SolverReport — while the fault-free msgpass run in the same race
    // stays ledger-clean and the reliable run still converges.
    let scenario = Scenario::paper("fault-ledger", 25)
        .with_solvers(vec![
            SolverSpec::parse("msgpass:2:4:mod").expect("plain"),
            SolverSpec::parse("msgpass:2:4:mod:drop0.1:crash0@30+15:rel").expect("faulted"),
        ])
        .with_steps(600)
        .with_stride(100)
        .with_rounds(2)
        .with_threads(1)
        .with_seed(19);
    let report = scenario.run().expect("fault scenario runs");
    let plain = report.get("msgpass:2:4:mod").expect("plain report");
    assert!(!plain.faults.any(), "ideal-network runs must stay ledger-clean");
    let faulted = report
        .get("msgpass:2:4:mod:drop0.1:crash0@30+15:rel")
        .expect("faulted report");
    assert!(faulted.faults.messages_dropped > 0, "a 10% plan must drop frames");
    assert!(faulted.faults.retransmits > 0, "reliable mode must retransmit through drops");
    assert_eq!(
        faulted.faults.recoveries, 2,
        "one crash window per round, two rounds absorbed"
    );
    assert!(
        faulted.final_error < 1e-3,
        "reliable delivery must keep converging under the plan, got {}",
        faulted.final_error
    );
}

#[test]
fn shipped_fig2_scenario_reproduces_the_fig2_harness_bit_for_bit() {
    // The acceptance pin: `run-scenario examples/fig2_scenario.json`
    // must carry the legacy `harness::fig2` trajectory exactly — the
    // harness is a preset over the same engine path, and the presence of
    // the baseline estimators must not perturb the kaczmarz stream.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package sits inside the repo")
        .join("examples/fig2_scenario.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let scenario = Scenario::from_json_str(&text).expect("shipped fig2 scenario parses");
    assert_eq!(scenario.graph, GraphSpec::ErThreshold { n: 60, threshold: 0.5 });
    for required in [
        EstimatorSpec::Kaczmarz,
        EstimatorSpec::DegreeWeighted,
        EstimatorSpec::RandomWalk,
    ] {
        assert!(
            scenario.estimators().contains(&required),
            "fig2 scenario must race {}",
            required.key()
        );
    }

    let report = scenario.run().expect("runs on paper:60");
    assert_eq!(report.estimator_reports().len(), 3);
    let kacz = report.get_estimator("kaczmarz").expect("Algorithm 2 ran");

    let legacy = fig2::run(&fig2::Fig2Config {
        n: 60,
        threshold: 0.5,
        rounds: scenario.rounds,
        steps: scenario.steps,
        stride: scenario.stride,
        seed: scenario.seed,
        threads: 2,
    });
    assert_eq!(
        kacz.trajectory.mean, legacy.avg.mean,
        "engine and fig2 harness must produce the identical trajectory"
    );
    assert_eq!(kacz.trajectory.variance, legacy.avg.variance);
    assert_eq!(kacz.final_size_rel_err, legacy.final_size_rel_err);
    assert_eq!(kacz.decay_rate, legacy.rate);
    // And the race is meaningful: Algorithm 2 contracts by decades, and
    // even the slower non-uniform site baselines contract clearly.
    assert!(kacz.final_error < 1e-2 * kacz.trajectory.mean[0], "{}", kacz.final_error);
    for r in report.estimator_reports() {
        assert!(
            r.final_error < 0.1 * r.trajectory.mean[0],
            "{} barely converged: {}",
            r.spec.key(),
            r.final_error
        );
    }
}

#[test]
fn file_graph_scenario_matches_the_in_memory_graph() {
    // Close the untested GraphSpec::File engine path: write a generated
    // graph to disk, run the identical scenario from the file, and pin
    // that the reports agree bit-for-bit with the in-memory run.
    let seed = 77u64;
    let g = generators::er_threshold(30, 0.5, seed);
    let dir = std::env::temp_dir().join(format!("prmp_filegraph_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir");
    let path = dir.join("er30.txt");
    pagerank_mp::graph::io::save(&g, &path).expect("writes the edge list");

    let mk = |graph: GraphSpec| {
        Scenario::new("file-vs-mem", graph)
            .with_solvers(vec![SolverSpec::Mp, SolverSpec::Dense])
            .with_steps(400)
            .with_stride(100)
            .with_rounds(2)
            .with_threads(1)
            .with_seed(seed)
    };
    let mem = mk(GraphSpec::ErThreshold { n: 30, threshold: 0.5 }).run().expect("mem runs");
    let file = mk(GraphSpec::file(path.to_str().expect("utf8")))
        .run()
        .expect("file runs");
    assert_eq!(mem.solver_reports().len(), file.solver_reports().len());
    for (a, b) in mem.solver_reports().iter().zip(file.solver_reports()) {
        assert_eq!(a.spec, b.spec);
        assert_eq!(
            a.trajectory.mean, b.trajectory.mean,
            "{}: the loaded graph must replay the generated graph exactly",
            a.spec.key()
        );
        assert_eq!(a.total_stats, b.total_stats, "{}", a.spec.key());
    }
    // Size estimation over the file path, too (the loaded ER graph is
    // strongly connected).
    let se = Scenario::new("file-se", GraphSpec::file(path.to_str().expect("utf8")))
    .with_estimators(vec![EstimatorSpec::Kaczmarz])
    .with_steps(400)
    .with_stride(200)
    .with_rounds(2)
    .with_threads(1)
    .with_seed(seed)
    .run()
    .expect("size estimation runs from a file graph");
    let r = &se.estimator_reports()[0];
    assert!(r.final_error < r.trajectory.mean[0]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn file_graph_dangling_policy_flows_from_the_spec() {
    // A chain graph has one sink; the `file:<path>:<policy>` suffix must
    // select how the loader repairs it.
    let g = generators::chain(6);
    let dir = std::env::temp_dir().join(format!("prmp_filepolicy_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir");
    let path = dir.join("chain6.txt");
    pagerank_mp::graph::io::save(&g, &path).expect("writes the edge list");
    let p = path.to_str().expect("utf8");

    let err = GraphSpec::parse(&format!("file:{p}:error"))
        .expect("parses")
        .build(0)
        .expect_err("the error policy must surface the sink");
    assert!(err.contains("dangling"), "{err}");

    let selfloop = GraphSpec::parse(&format!("file:{p}:selfloop"))
        .expect("parses")
        .build(0)
        .expect("selfloop repair");
    assert!(selfloop.dangling().is_empty());
    assert_eq!(selfloop.out(5), &[5], "the sink should link only to itself");

    // Bare form keeps the historical LinkAll default.
    let linkall = GraphSpec::parse(&format!("file:{p}")).expect("parses").build(0).expect("loads");
    assert_eq!(linkall.out_degree(5), 5, "LinkAll links the sink to every other page");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn typed_coordinator_adapter_exposes_runtime_metrics() {
    let graph = GraphSpec::paper(20).build(3).expect("builds");
    let spec = SolverSpec::parse("coordinator:sequential:uniform:zero").expect("parses");
    let mut coord = CoordinatorSolver::from_spec(&graph, 0.85, 11, &spec).expect("coordinator");
    let report = coord.drive(250);
    assert_eq!(report.metrics.activations, 250);
    assert_eq!(coord.metrics().activations, 250);
    assert!(coord.residual().len() == 20);
}
