//! Engine-level integration tests: the solver registry, scenario JSON
//! round-trips, determinism, and the distributed-vs-matrix-form
//! equivalence through the declarative API.

use pagerank_mp::engine::{
    CoordinatorSolver, GraphSpec, ReferencePolicy, Scenario, ScenarioReport, SolverSpec,
};
use pagerank_mp::util::json::Json;

fn small(name: &str, solvers: Vec<SolverSpec>) -> Scenario {
    Scenario::paper(name, 25)
        .with_solvers(solvers)
        .with_steps(800)
        .with_stride(100)
        .with_rounds(3)
        .with_threads(2)
        .with_seed(41)
}

#[test]
fn registry_round_trips_every_solver_name() {
    let all = SolverSpec::all();
    assert!(all.len() >= 10, "the registry must cover the 10+ variants");
    for spec in &all {
        let key = spec.key();
        let back = SolverSpec::parse(&key)
            .unwrap_or_else(|e| panic!("canonical key {key:?} failed to parse: {e}"));
        assert_eq!(&back, spec, "{key} did not round-trip");
    }
    // Baselines are a subset of the registry.
    for spec in SolverSpec::all_baselines() {
        assert!(SolverSpec::parse(&spec.key()).is_ok());
    }
}

#[test]
fn scenario_json_serialize_deserialize_run_is_deterministic() {
    let scenario = small("det", vec![SolverSpec::Mp, SolverSpec::LeiChen]);
    let text = scenario.to_json().render();
    let reparsed = Scenario::from_json_str(&text).expect("scenario JSON round-trips");
    assert_eq!(reparsed, scenario);

    let a = scenario.run().expect("original runs");
    let b = reparsed.run().expect("reparsed runs");
    assert_eq!(a.reports.len(), b.reports.len());
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.spec, rb.spec);
        // Same seed ⇒ identical mean trajectory, bit for bit.
        assert_eq!(ra.trajectory.mean, rb.trajectory.mean);
        assert_eq!(ra.trajectory.variance, rb.trajectory.variance);
        assert_eq!(ra.total_stats, rb.total_stats);
    }
}

#[test]
fn zero_latency_coordinator_matches_matrix_mp_bit_for_bit() {
    // The sequential zero-latency coordinator and the matrix-form MP are
    // the same algorithm realized at two layers; through the Scenario
    // seed protocol they replay identical activation sequences and the
    // recorded trajectories must agree exactly.
    let scenario = small(
        "coord-vs-mp",
        vec![SolverSpec::Mp, SolverSpec::sequential_coordinator()],
    );
    let report = scenario.run().expect("runs");
    let mp = report.get("mp").expect("mp ran");
    let coord = report
        .get("coordinator:sequential:uniform:zero")
        .expect("coordinator ran");
    assert_eq!(
        mp.trajectory.mean, coord.trajectory.mean,
        "distributed and matrix forms must be bit-identical under an ideal network"
    );
    assert_eq!(mp.trajectory.variance, coord.trajectory.variance);
    // Same activation sequence ⇒ same logical read counts (no self-loops
    // in the ER-threshold model, so wire writes match too).
    assert_eq!(mp.total_stats.reads, coord.total_stats.reads);
    assert_eq!(mp.total_stats.writes, coord.total_stats.writes);
}

#[test]
fn reference_policies_agree() {
    let exact = small("ref-exact", vec![SolverSpec::Mp]);
    let power = exact
        .clone()
        .with_reference(ReferencePolicy::Power { tol: 1e-14 });
    let a = exact.run().expect("exact runs");
    let b = power.run().expect("power runs");
    // Same solver stream, near-identical reference ⇒ near-identical
    // trajectories.
    for (ea, eb) in a.reports[0].trajectory.mean.iter().zip(&b.reports[0].trajectory.mean) {
        assert!((ea - eb).abs() < 1e-9, "{ea} vs {eb}");
    }
}

#[test]
fn every_registry_solver_runs_inside_a_scenario() {
    let scenario = Scenario::paper("all-solvers", 12)
        .with_solvers(SolverSpec::all())
        .with_steps(120)
        .with_stride(40)
        .with_rounds(2)
        .with_threads(2)
        .with_seed(9);
    let report = scenario.run().expect("every registered solver must run");
    assert_eq!(report.reports.len(), SolverSpec::all().len());
    for r in &report.reports {
        assert_eq!(r.trajectory.mean.len(), 4, "{}: t = 0,40,80,120", r.spec.key());
        assert!(
            r.trajectory.mean.iter().all(|v| v.is_finite()),
            "{}: non-finite trajectory",
            r.spec.key()
        );
        assert!(r.total_stats.activated > 0, "{}: nothing activated", r.spec.key());
    }
}

#[test]
fn shipped_fig1_scenario_file_parses_and_names_the_paper_setup() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("package sits inside the repo")
        .join("examples/fig1_scenario.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    let scenario = Scenario::from_json_str(&text).expect("shipped scenario parses");
    assert_eq!(scenario.graph, GraphSpec::ErThreshold { n: 100, threshold: 0.5 });
    assert_eq!(scenario.alpha, 0.85);
    for required in ["mp", "ishii-tempo", "lei-chen"] {
        assert!(
            scenario.solvers.iter().any(|s| s.key() == required),
            "fig1 scenario must include {required}"
        );
    }
}

#[test]
fn fig1_ordering_reproduced_at_reduced_scale() {
    // The acceptance ordering of the full `run-scenario
    // examples/fig1_scenario.json` run, pinned here at test scale: MP's
    // fitted decay rate is strictly better (smaller) than Ishii–Tempo's
    // and Lei–Chen's.
    let scenario = Scenario::paper("fig1-ordering", 30)
        .with_solvers(vec![
            SolverSpec::Mp,
            SolverSpec::IshiiTempo,
            SolverSpec::LeiChen,
        ])
        .with_steps(9_000)
        .with_stride(300)
        .with_rounds(6)
        .with_threads(4)
        .with_seed(2017);
    let report = scenario.run().expect("runs");
    let mp = report.get("mp").expect("mp").decay_rate;
    let it = report.get("ishii-tempo").expect("it").decay_rate;
    let lc = report.get("lei-chen").expect("lc").decay_rate;
    assert!(mp < it, "MP ({mp}) must out-decay Ishii–Tempo ({it})");
    assert!(mp < lc, "MP ({mp}) must out-decay Lei–Chen ({lc})");
    assert_eq!(report.rate_ordering()[0].0, "mp");
}

/// The perf-trajectory artifact: BENCH_scenario.json carries per-solver
/// final error, decay rate, communication counts and wall time.
#[test]
fn bench_json_is_machine_readable() {
    let report: ScenarioReport = small("bench-dump", vec![SolverSpec::Mp])
        .run()
        .expect("runs");
    let dir = std::env::temp_dir().join(format!("prmp_engine_{}", std::process::id()));
    let path = dir.join("BENCH_scenario.json");
    report.write_bench_json(&path).expect("writes");
    let parsed = Json::parse(&std::fs::read_to_string(&path).expect("readable"))
        .expect("valid JSON on disk");
    let solvers = parsed.get("solvers").and_then(Json::as_array).expect("solvers array");
    assert_eq!(solvers.len(), 1);
    for field in ["name", "final_error", "decay_rate", "reads", "writes", "wall_ms"] {
        assert!(
            solvers[0].get(field).is_some(),
            "BENCH_scenario.json solver entry missing {field:?}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn async_coordinator_scenario_keeps_overlap_and_converges() {
    // Async + latency through the declarative API: recording happens in
    // stride-sized chunks, so activations overlap within a chunk instead
    // of being quiesced one by one.
    let spec = SolverSpec::parse("coordinator:async:clocks:const:0.2").expect("parses");
    let scenario = Scenario::paper("async-coord", 40)
        .with_solvers(vec![spec])
        .with_steps(600)
        .with_stride(200)
        .with_rounds(2)
        .with_threads(1)
        .with_seed(17);
    let report = scenario.run().expect("runs");
    let r = &report.reports[0];
    assert_eq!(r.trajectory.mean.len(), 4); // t = 0,200,400,600
    assert!(
        r.final_error < r.trajectory.mean[0],
        "async coordinator must make progress"
    );
    // Each round completes at least its budget (drain may add a few).
    assert!(r.total_stats.activated >= 2 * 600);
}

#[test]
fn typed_coordinator_adapter_exposes_runtime_metrics() {
    let graph = GraphSpec::paper(20).build(3).expect("builds");
    let spec = SolverSpec::parse("coordinator:sequential:uniform:zero").expect("parses");
    let mut coord = CoordinatorSolver::from_spec(&graph, 0.85, 11, &spec).expect("coordinator");
    let report = coord.drive(250);
    assert_eq!(report.metrics.activations, 250);
    assert_eq!(coord.metrics().activations, 250);
    assert!(coord.residual().len() == 20);
}
