//! Cross-module integration tests: whole-system flows that exercise
//! several layers together (graph IO → algorithms → harness → reports),
//! without the PJRT runtime (see runtime_e2e.rs for that).

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::dynamic::{DynamicMatchingPursuit, EdgeEvent};
use pagerank_mp::algo::monte_carlo::MonteCarlo;
use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::algo::power_iteration::JacobiPowerIteration;
use pagerank_mp::algo::stopping::RankingCertifier;
use pagerank_mp::coordinator::{Coordinator, CoordinatorConfig, Mode, SamplerKind};
use pagerank_mp::graph::{generators, io as graph_io, DanglingPolicy};
use pagerank_mp::harness::{fig1, fig2};
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::linalg::vector;
use pagerank_mp::network::LatencyModel;
use pagerank_mp::util::rng::Rng;

const ALPHA: f64 = 0.85;

/// Every engine agrees on the same graph: exact solve, power iteration,
/// matrix-form MP, distributed coordinator, and Monte-Carlo (loosely).
#[test]
fn all_engines_agree() {
    let g = generators::er_threshold(60, 0.5, 1001);
    let x_star = exact_pagerank(&g, ALPHA);

    let mut pi = JacobiPowerIteration::new(&g, ALPHA);
    pi.run_to_tolerance(1e-13, 2000);
    assert!(vector::dist_inf(&pi.estimate(), &x_star) < 1e-10, "power iteration");

    let mut mp = MatchingPursuit::new(&g, ALPHA);
    let mut rng = Rng::seeded(5);
    for _ in 0..200_000 {
        mp.step(&mut rng);
    }
    assert!(vector::dist_inf(&mp.estimate(), &x_star) < 1e-9, "matrix-form MP");

    let cfg = CoordinatorConfig::default().with_seed(6).with_alpha(ALPHA);
    let mut coord = Coordinator::new(&g, cfg);
    coord.run(200_000);
    assert!(
        vector::dist_inf(&coord.estimate(), &x_star) < 1e-9,
        "distributed coordinator"
    );

    let mut mc = MonteCarlo::new(&g, ALPHA);
    let mut rng = Rng::seeded(7);
    for _ in 0..4000 {
        mc.round(&mut rng);
    }
    let agr = pagerank_mp::util::stats::ranking_agreement(&mc.estimate(), &x_star);
    assert!(agr > 0.9, "monte-carlo ranking agreement {agr}");
}

/// Graph IO round-trips through a file and the ranking is unchanged.
#[test]
fn io_round_trip_preserves_ranking() {
    let g = generators::barabasi_albert(150, 3, 1002);
    let dir = std::env::temp_dir().join(format!("prmp_int_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir");
    let path = dir.join("ba.txt");
    graph_io::save(&g, &path).expect("save");
    let g2 = graph_io::load(&path, DanglingPolicy::Error).expect("load");
    assert_eq!(g, g2);
    let x1 = exact_pagerank(&g, ALPHA);
    let x2 = exact_pagerank(&g2, ALPHA);
    assert_eq!(
        pagerank_mp::util::stats::ranking(&x1),
        pagerank_mp::util::stats::ranking(&x2)
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The stopping criterion is sound along a full distributed run.
#[test]
fn certification_sound_on_coordinator_run() {
    let g = generators::er_threshold(40, 0.5, 1003);
    let x_star = exact_pagerank(&g, ALPHA);
    let cert = RankingCertifier::new(&g, ALPHA);
    let cfg = CoordinatorConfig::default()
        .with_seed(8)
        .with_latency(LatencyModel::Exponential { mean: 0.05 });
    let mut coord = Coordinator::new(&g, cfg);
    for _ in 0..20 {
        coord.run(2_000);
        let x = coord.estimate();
        let rn2 = vector::norm2_sq(&coord.residual());
        let eps = cert.epsilon(rn2);
        let true_err = vector::dist_inf(&x, &x_star);
        assert!(true_err <= eps + 1e-12, "bound violated: {true_err} > {eps}");
    }
    // after 40k activations at N=40 some prefix certifies and is correct
    let x = coord.estimate();
    let rn2 = vector::norm2_sq(&coord.residual());
    let c = cert.certify(&x, rn2);
    assert!(c.certified_prefix > 0);
    let true_ranking = pagerank_mp::util::stats::ranking(&x_star);
    let k = c.certified_prefix.min(5);
    assert_eq!(&c.ranking[..k], &true_ranking[..k]);
}

/// Dynamic tracking across a long churn sequence stays exact (eq. 11) and
/// converges to each successive topology's PageRank.
#[test]
fn dynamic_tracking_over_churn() {
    let g = generators::er_threshold(30, 0.5, 1004);
    let mut dmp = DynamicMatchingPursuit::new(g, ALPHA);
    let mut rng = Rng::seeded(9);
    let mut churn = Rng::seeded(10);
    for event in 0..8 {
        for _ in 0..45_000 {
            dmp.step(&mut rng);
        }
        let x_star = exact_pagerank(dmp.graph(), ALPHA);
        assert!(
            vector::dist_inf(dmp.estimate(), &x_star) < 1e-4,
            "tracking lost at event {event}: {}",
            vector::dist_inf(dmp.estimate(), &x_star)
        );
        // random valid mutation
        loop {
            let s = churn.below(30);
            let d = churn.below(30);
            if s == d {
                continue;
            }
            let ev = if dmp.graph().has_edge(s, d) {
                if dmp.graph().out_degree(s) <= 1 {
                    continue;
                }
                EdgeEvent::Remove { src: s, dst: d }
            } else {
                EdgeEvent::Add { src: s, dst: d }
            };
            dmp.apply_event(ev).expect("valid event");
            break;
        }
        assert!(dmp.conservation_error() < 1e-9, "eq. 11 broken at event {event}");
    }
}

/// Scaled-down Figure 1 + Figure 2 end-to-end through the harness,
/// asserting every paper claim.
#[test]
fn figures_reproduce_claims_small_scale() {
    let f1 = fig1::run(&fig1::Fig1Config {
        n: 30,
        rounds: 8,
        steps: 10_000,
        stride: 250,
        seed: 77,
        threads: 4,
        ..Default::default()
    });
    for (claim, ok) in f1.claims() {
        assert!(ok, "fig1 claim failed: {claim}\n{:#?}", f1.verdict);
    }
    let f2 = fig2::run(&fig2::Fig2Config {
        n: 30,
        rounds: 16,
        steps: 5_000,
        stride: 100,
        seed: 78,
        threads: 4,
        ..Default::default()
    });
    for (claim, ok) in f2.claims() {
        assert!(ok, "fig2 claim failed: {claim} (rate {} bound {})", f2.rate, f2.predicted_bound);
    }
}

/// Async coordinator on a sparse graph: overlap happens, and the final
/// state still satisfies conservation against the true topology.
#[test]
fn async_overlap_preserves_exactness() {
    let g = generators::erdos_renyi(400, 0.004, 1005);
    let cfg = CoordinatorConfig::default()
        .with_seed(11)
        .with_mode(Mode::Async)
        .with_sampler(SamplerKind::ExponentialClocks)
        .with_latency(LatencyModel::Uniform { lo: 0.1, hi: 0.4 });
    let mut coord = Coordinator::new(&g, cfg);
    let rep = coord.run(5_000);
    assert!(rep.metrics.peak_overlap > 1, "no overlap achieved");
    let b = pagerank_mp::linalg::dense::DenseMatrix::b_matrix(&g, ALPHA);
    let bx = b.matvec(&coord.estimate());
    for (i, (bxi, ri)) in bx.iter().zip(coord.residual()).enumerate() {
        assert!(
            (bxi + ri - (1.0 - ALPHA)).abs() < 1e-10,
            "conservation broken at {i}"
        );
    }
}

/// Message accounting equals the §II-D cost model across samplers.
#[test]
fn message_cost_model_holds() {
    let g = generators::er_threshold(50, 0.5, 1006);
    for sampler in [SamplerKind::Uniform, SamplerKind::ExponentialClocks] {
        let cfg = CoordinatorConfig::default().with_seed(12).with_sampler(sampler);
        let mut coord = Coordinator::new(&g, cfg);
        let rep = coord.run(1_000);
        // logical reads == logical writes + self-loop short circuits; on
        // this generator there are no self-loops, so they are equal.
        assert_eq!(rep.metrics.logical_reads(), rep.metrics.logical_writes());
        // and per activation they average the mean out-degree
        let per_act = rep.metrics.logical_reads() as f64 / rep.metrics.activations as f64;
        let mean_deg = g.m() as f64 / g.n() as f64;
        assert!(
            (per_act - mean_deg).abs() < 0.15 * mean_deg,
            "per-activation reads {per_act} vs mean degree {mean_deg}"
        );
    }
}
