//! End-to-end runtime tests: load the AOT artifacts (built by `make
//! artifacts`), execute them via PJRT, and cross-validate the dense
//! JAX/Pallas engine against the sparse f64 Rust implementation on
//! *identical* activation sequences.
//!
//! These tests are skipped (with a loud message) if `artifacts/` has not
//! been built — run `make artifacts` first.

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::algo::size_estimation::SizeEstimator;
use pagerank_mp::graph::generators;
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::linalg::vector;
use pagerank_mp::runtime::{
    artifact_dir, Engine, JacobiRunner, MpChunkRunner, ResidualNormRunner, SizeChunkRunner,
};
use pagerank_mp::util::rng::Rng;

const ALPHA: f64 = 0.85;

fn engine_or_skip() -> Option<Engine> {
    let dir = artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "SKIP: no artifacts at {} — run `make artifacts`",
            dir.display()
        );
        return None;
    }
    Some(Engine::load(&dir).expect("engine loads"))
}

#[test]
fn engine_loads_and_reports_platform() {
    let Some(engine) = engine_or_skip() else { return };
    let platform = engine.platform();
    assert!(!platform.is_empty());
    assert!(!engine.manifest().artifacts.is_empty());
}

#[test]
fn mp_chunk_matches_sparse_rust_trajectory() {
    let Some(mut engine) = engine_or_skip() else { return };
    // The paper's graph model at its experiment scale.
    let g = generators::er_threshold(100, 0.5, 42);
    let mut runner = MpChunkRunner::new(&mut engine, &g, ALPHA).expect("runner");
    let t = runner.chunk_len();

    let mut mp = MatchingPursuit::new(&g, ALPHA);
    let mut rng = Rng::seeded(777);
    for chunk in 0..4 {
        let ks: Vec<usize> = (0..t).map(|_| rng.below(100)).collect();
        let trace = runner.run_chunk(&mut engine, &ks).expect("chunk runs");
        assert_eq!(trace.len(), t);
        for &k in &ks {
            mp.step_at(k);
        }
        // identical activation sequence => same trajectory to f32 tolerance
        let dense_x = runner.estimate();
        let sparse_x = mp.estimate();
        let err = vector::dist_inf(&dense_x, &sparse_x);
        assert!(err < 5e-4, "chunk {chunk}: dense vs sparse drifted by {err}");
        // trace endpoint agrees with the sparse incremental ‖r‖²
        let dr = (trace[t - 1] - mp.residual_norm_sq()).abs();
        assert!(dr < 5e-4, "chunk {chunk}: trace drift {dr}");
    }
    // padding must have stayed exactly inert through all chunks
    assert_eq!(runner.padding_tail_abs_max(), 0.0);
}

#[test]
fn mp_chunk_trace_is_monotone_nonincreasing() {
    let Some(mut engine) = engine_or_skip() else { return };
    let g = generators::er_threshold(100, 0.5, 43);
    let mut runner = MpChunkRunner::new(&mut engine, &g, ALPHA).expect("runner");
    let t = runner.chunk_len();
    let mut rng = Rng::seeded(44);
    let ks: Vec<usize> = (0..t).map(|_| rng.below(100)).collect();
    let trace = runner.run_chunk(&mut engine, &ks).expect("chunk runs");
    for w in trace.windows(2) {
        assert!(w[1] <= w[0] + 1e-6, "projection increased the residual");
    }
}

#[test]
fn mp_chunk_rejects_bad_inputs() {
    let Some(mut engine) = engine_or_skip() else { return };
    let g = generators::er_threshold(50, 0.5, 45);
    let mut runner = MpChunkRunner::new(&mut engine, &g, ALPHA).expect("runner");
    let t = runner.chunk_len();
    // wrong length
    assert!(runner.run_chunk(&mut engine, &vec![0; t - 1]).is_err());
    // out-of-range activation (padding index — must be refused, not inert
    // by accident)
    let mut ks = vec![0usize; t];
    ks[3] = 50;
    assert!(runner.run_chunk(&mut engine, &ks).is_err());
}

#[test]
fn jacobi_runner_converges_to_exact() {
    let Some(mut engine) = engine_or_skip() else { return };
    let g = generators::er_threshold(100, 0.5, 46);
    let x_star = exact_pagerank(&g, ALPHA);
    let mut runner = JacobiRunner::new(&mut engine, &g, ALPHA).expect("runner");
    let chunks = runner
        .run_to_tolerance(&mut engine, 1e-7, 100)
        .expect("runs");
    assert!(chunks < 100, "did not reach tolerance");
    let err = vector::dist_inf(&runner.estimate(), &x_star);
    assert!(err < 1e-4, "err={err}");
}

#[test]
fn size_chunk_matches_sparse_rust() {
    let Some(mut engine) = engine_or_skip() else { return };
    let g = generators::er_threshold(100, 0.5, 47);
    let mut runner = SizeChunkRunner::new(&mut engine, &g).expect("runner");
    let t = runner.chunk_len();
    let mut est = SizeEstimator::new(&g).expect("strongly connected");
    let mut rng = Rng::seeded(48);
    for _ in 0..3 {
        let ks: Vec<usize> = (0..t).map(|_| rng.below(100)).collect();
        let trace = runner.run_chunk(&mut engine, &ks).expect("chunk runs");
        for &k in &ks {
            est.step_at(k);
        }
        let err = vector::dist_inf(&runner.s(), est.s());
        assert!(err < 5e-5, "dense vs sparse size est drifted by {err}");
        // trace endpoint = ‖s - 1/N‖²
        let want = est.error_sq();
        assert!((trace[t - 1] - want).abs() < 5e-5);
    }
}

#[test]
fn residual_norm_checks_conservation() {
    let Some(mut engine) = engine_or_skip() else { return };
    let g = generators::er_threshold(100, 0.5, 49);
    let checker = ResidualNormRunner::new(&mut engine, &g, ALPHA).expect("runner");
    // At x = 0 the residual is y itself: ‖r‖² = N(1-α)².
    let (r, rn2) = checker.run(&mut engine, &vec![0.0; 100]).expect("runs");
    assert!((rn2 - 100.0 * 0.15 * 0.15).abs() < 1e-4);
    assert!(r.iter().all(|&v| (v - 0.15).abs() < 1e-6));
    // At x = x* the residual vanishes.
    let x_star = exact_pagerank(&g, ALPHA);
    let (_, rn2) = checker.run(&mut engine, &x_star).expect("runs");
    assert!(rn2 < 1e-8, "rn2={rn2}");
}

#[test]
fn dense_engine_converges_on_paper_workload() {
    // The dense path run standalone long enough to rank pages correctly.
    let Some(mut engine) = engine_or_skip() else { return };
    let g = generators::er_threshold(100, 0.5, 50);
    let x_star = exact_pagerank(&g, ALPHA);
    let mut runner = MpChunkRunner::new(&mut engine, &g, ALPHA).expect("runner");
    let t = runner.chunk_len();
    let mut rng = Rng::seeded(51);
    for _ in 0..40 {
        // ~5k activations
        let ks: Vec<usize> = (0..t).map(|_| rng.below(100)).collect();
        runner.run_chunk(&mut engine, &ks).expect("chunk runs");
    }
    let agr = pagerank_mp::util::stats::ranking_agreement(&runner.estimate(), &x_star);
    assert!(agr > 0.95, "ranking agreement {agr}");
}

#[test]
fn larger_graph_uses_bigger_artifact() {
    let Some(mut engine) = engine_or_skip() else { return };
    let g = generators::er_threshold(200, 0.5, 52);
    let runner = MpChunkRunner::new(&mut engine, &g, ALPHA).expect("runner");
    assert!(runner.padded_size() >= 200);
    let too_big = generators::er_threshold(300, 0.5, 53);
    assert!(
        MpChunkRunner::new(&mut engine, &too_big, ALPHA).is_err(),
        "300 pages cannot fit the default 256-padded artifacts"
    );
}
