//! END-TO-END driver: proves all three layers compose on the paper's own
//! workload (EXPERIMENTS.md §E2E records a run of this binary).
//!
//!  L1/L2  JAX + Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`
//!         (`make artifacts`), executed here via the PJRT runtime;
//!  L3     the Rust distributed coordinator (page agents + simulated
//!         network + exponential clocks);
//!  check  both engines replay the *identical* activation sequence and
//!         must agree to f32 tolerance step-for-step, and both must
//!         reproduce the paper's headline metric — exponential decay of
//!         (1/N)‖x_t − x*‖² at a rate no slower than 1 − σ²(B̂)/N.
//!
//! Run with: `make artifacts && cargo run --release --example end_to_end`

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::engine::{CoordinatorSolver, GraphSpec, SolverSpec};
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::linalg::vector;
use pagerank_mp::runtime::{Engine, MpChunkRunner, ResidualNormRunner};
use pagerank_mp::util::rng::Rng;

fn main() {
    let n = 100;
    let alpha = 0.85;
    let seed = 20_17;

    println!("=== END-TO-END: paper workload (N={n}, ER-threshold 0.5, α={alpha}) ===\n");
    let graph = GraphSpec::ErThreshold { n, threshold: 0.5 }
        .build(seed)
        .expect("paper graph builds");
    let x_star = exact_pagerank(&graph, alpha);
    let bound = pagerank_mp::linalg::spectral::mp_contraction_rate(&graph, alpha);
    println!("predicted Prop.2 contraction: 1 - σ²(B̂)/N = {bound:.6}");

    // ---- L1/L2: PJRT dense engine over the Pallas-kernel artifacts ------
    let mut engine = match Engine::load_default() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("FATAL: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", engine.platform());
    let mut dense = MpChunkRunner::new(&mut engine, &graph, alpha).expect("dense runner");
    let checker = ResidualNormRunner::new(&mut engine, &graph, alpha).expect("norm runner");
    let t_chunk = dense.chunk_len();

    // ---- reference sparse replay (same activation stream) ---------------
    let mut sparse = MatchingPursuit::new(&graph, alpha);

    // ---- run both engines on the identical activation sequence ----------
    let chunks = 96; // ~12k activations
    let mut rng = Rng::seeded(seed as u64);
    let mut errs = Vec::new();
    let t0 = std::time::Instant::now();
    let mut dense_time = std::time::Duration::ZERO;
    for c in 0..chunks {
        let ks: Vec<usize> = (0..t_chunk).map(|_| rng.below(n)).collect();
        let td = std::time::Instant::now();
        dense.run_chunk(&mut engine, &ks).expect("dense chunk");
        dense_time += td.elapsed();
        for &k in &ks {
            sparse.step_at(k);
        }
        let drift = vector::dist_inf(&dense.estimate(), &sparse.estimate());
        assert!(drift < 1e-3, "engines diverged at chunk {c}: {drift}");
        errs.push(vector::dist_sq(&sparse.estimate(), &x_star) / n as f64);
        if c % 16 == 0 {
            println!(
                "chunk {c:>3}: t={:>6}  (1/N)‖x-x*‖² = {:.3e}  dense↔sparse drift {drift:.1e}",
                (c + 1) * t_chunk,
                errs.last().expect("nonempty"),
            );
        }
    }
    let steps_done = chunks * t_chunk;
    println!(
        "\ndense engine: {} steps in {:?} ({:.1} µs/step amortized)",
        steps_done,
        dense_time,
        dense_time.as_micros() as f64 / steps_done as f64
    );

    // headline metric: fitted decay rate vs the paper's bound
    let per_chunk = pagerank_mp::util::stats::decay_rate_above(&errs, 1e-28);
    let per_step = per_chunk.powf(1.0 / t_chunk as f64);
    println!("measured per-step rate {per_step:.6} (bound {bound:.6})");
    assert!(per_step <= bound + 1e-3, "exponential-rate claim failed");

    // eq. 11 conservation verified through the PJRT residual checker
    let (_, rn2) = checker.run(&mut engine, &sparse.estimate()).expect("checker");
    let incr = sparse.residual_norm_sq();
    println!("‖r‖² PJRT = {rn2:.6e} vs sparse incremental = {incr:.6e}");
    assert!((rn2 - incr).abs() / incr.max(1e-30) < 0.05 || (rn2 - incr).abs() < 1e-6);

    // ---- L3: the distributed coordinator on the same workload -----------
    println!("\n=== L3 distributed coordinator (async exponential clocks) ===");
    let spec = SolverSpec::parse("coordinator:async:clocks:uniform:0.05:0.15")
        .expect("registry spec parses");
    let mut coord =
        CoordinatorSolver::from_spec(&graph, alpha, seed as u64, &spec).expect("coordinator spec");
    let tw = std::time::Instant::now();
    let report = coord.drive(steps_done as u64);
    let wall = tw.elapsed();
    let coord_err = vector::dist_sq(&coord.estimate(), &x_star) / n as f64;
    println!("{}", report.metrics.render());
    println!(
        "coordinator: {} activations in {:?} ({:.0} act/s wall), err {coord_err:.3e}",
        steps_done,
        wall,
        steps_done as f64 / wall.as_secs_f64()
    );
    // §II-D claim: messages per activation = 2·N_k reads+replies + writes.
    let expected_msgs = 3.0 * graph.m() as f64 / n as f64;
    let measured = report.metrics.messages_per_activation();
    println!(
        "messages/activation {measured:.1} (expectation ≈ 3·mean N_k − self-loops = {expected_msgs:.1})"
    );
    assert!((measured - expected_msgs).abs() / expected_msgs < 0.15);

    // both engines agree on the ranking
    let agree_dense = pagerank_mp::util::stats::ranking_agreement(&dense.estimate(), &x_star);
    let agree_coord = pagerank_mp::util::stats::ranking_agreement(&coord.estimate(), &x_star);
    println!(
        "\nranking agreement vs exact: dense {agree_dense:.4}, coordinator {agree_coord:.4}"
    );
    assert!(agree_dense > 0.99 && agree_coord > 0.99);

    println!("\nelapsed total {:?}", t0.elapsed());
    println!("END-TO-END OK: all three layers compose and reproduce the paper's claim.");
}
