//! Web-scale-shaped ranking: a heavy-tailed Barabási–Albert graph ranked
//! by the distributed coordinator under realistic message latency, with
//! the §IV-4 stopping criterion certifying the top-k result.
//!
//! This is the scenario the paper's introduction motivates: per-page
//! agents, out-neighbour-only communication, asynchronous clocks. The
//! runtime is named through the engine's string registry — the same spec
//! string works in scenario JSON files — and driven through the typed
//! [`CoordinatorSolver`] adapter for metrics access.
//!
//! Run with: `cargo run --release --example webgraph_ranking`

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::stopping::RankingCertifier;
use pagerank_mp::engine::{CoordinatorSolver, GraphSpec, SolverSpec};
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::linalg::vector;

fn main() {
    let n = 1_000;
    let alpha = 0.85;
    // Preferential attachment: heavy-tailed in-degrees like a real web.
    let graph = GraphSpec::Family { family: "ba".into(), n }
        .build(99)
        .expect("ba family is registered");
    let stats = pagerank_mp::graph::stats::DegreeStats::compute(&graph);
    println!("{}\n", stats.render());

    // Asynchronous exponential clocks (paper Remark 1), sparse topology →
    // real overlap between activations; uniform-latency links. The spec
    // string is exactly what a scenario JSON would carry.
    let spec = SolverSpec::parse("coordinator:async:clocks:uniform:0.05:0.25")
        .expect("registry spec parses");
    let mut coord = CoordinatorSolver::from_spec(&graph, alpha, 5, &spec)
        .expect("spec names the coordinator");

    let x_star = exact_pagerank(&graph, alpha);
    let certifier = RankingCertifier::new(&graph, alpha);

    let mut total: u64 = 0;
    for round in 1..=8 {
        let budget = 50_000;
        let report = coord.drive(budget);
        total += budget;
        let x = coord.estimate();
        let r = coord.residual();
        let rnorm_sq = vector::norm2_sq(&r);
        let err = vector::dist_sq(&x, &x_star) / n as f64;
        let cert = certifier.certify(&x, rnorm_sq);
        println!(
            "after {total:>7} activations: err {err:.3e}, certified top-{:<4} \
             overlap {:>3}, deferred {:>6}, msgs/act {:.1}",
            cert.certified_prefix,
            report.metrics.peak_overlap,
            report.metrics.deferred,
            report.metrics.messages_per_activation(),
        );
        if round >= 2 && certifier.top_k_certified(&x, rnorm_sq, 10) {
            println!("\ntop-10 set certified by the §IV-4 criterion — stopping early.");
            break;
        }
    }

    let x = coord.estimate();
    let ranking = pagerank_mp::util::stats::ranking(&x);
    let true_ranking = pagerank_mp::util::stats::ranking(&x_star);
    println!("\n#  page   score      (true rank)");
    for (i, &p) in ranking.iter().take(10).enumerate() {
        let true_pos = true_ranking.iter().position(|&q| q == p).expect("page exists");
        println!("{:<2} {:<6} {:<10.4} ({})", i + 1, p, x[p], true_pos + 1);
    }
    assert_eq!(ranking[0], true_ranking[0], "top page must be correct");
    println!("\nwebgraph_ranking OK");
}
