//! Web-scale-shaped ranking: a heavy-tailed Barabási–Albert graph ranked
//! by the distributed coordinator under realistic message latency, with
//! the §IV-4 stopping criterion certifying the top-k result.
//!
//! This is the scenario the paper's introduction motivates: per-page
//! agents, out-neighbour-only communication, asynchronous clocks.
//!
//! Run with: `cargo run --release --example webgraph_ranking`

use pagerank_mp::algo::stopping::RankingCertifier;
use pagerank_mp::coordinator::{Coordinator, CoordinatorConfig, Mode, SamplerKind};
use pagerank_mp::graph::generators;
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::linalg::vector;
use pagerank_mp::network::LatencyModel;

fn main() {
    let n = 1_000;
    let alpha = 0.85;
    // Preferential attachment: heavy-tailed in-degrees like a real web.
    let graph = generators::barabasi_albert(n, 4, 99);
    let stats = pagerank_mp::graph::stats::DegreeStats::compute(&graph);
    println!("{}\n", stats.render());

    // Asynchronous exponential clocks (paper Remark 1), sparse topology →
    // real overlap between activations; uniform-latency links.
    let cfg = CoordinatorConfig::default()
        .with_alpha(alpha)
        .with_seed(5)
        .with_mode(Mode::Async)
        .with_sampler(SamplerKind::ExponentialClocks)
        .with_latency(LatencyModel::Uniform { lo: 0.05, hi: 0.25 });
    let mut coord = Coordinator::new(&graph, cfg);

    let x_star = exact_pagerank(&graph, alpha);
    let certifier = RankingCertifier::new(&graph, alpha);

    let mut total: u64 = 0;
    for round in 1..=8 {
        let budget = 50_000;
        let report = coord.run(budget);
        total += budget;
        let x = coord.estimate();
        let r = coord.residual();
        let rnorm_sq = vector::norm2_sq(&r);
        let err = vector::dist_sq(&x, &x_star) / n as f64;
        let cert = certifier.certify(&x, rnorm_sq);
        println!(
            "after {total:>7} activations: err {err:.3e}, certified top-{:<4} \
             overlap {:>3}, deferred {:>6}, msgs/act {:.1}",
            cert.certified_prefix,
            report.metrics.peak_overlap,
            report.metrics.deferred,
            report.metrics.messages_per_activation(),
        );
        if round >= 2 && certifier.top_k_certified(&x, rnorm_sq, 10) {
            println!("\ntop-10 set certified by the §IV-4 criterion — stopping early.");
            break;
        }
    }

    let x = coord.estimate();
    let ranking = pagerank_mp::util::stats::ranking(&x);
    let true_ranking = pagerank_mp::util::stats::ranking(&x_star);
    println!("\n#  page   score      (true rank)");
    for (i, &p) in ranking.iter().take(10).enumerate() {
        let true_pos = true_ranking.iter().position(|&q| q == p).expect("page exists");
        println!("{:<2} {:<6} {:<10.4} ({})", i + 1, p, x[p], true_pos + 1);
    }
    assert_eq!(ranking[0], true_ranking[0], "top page must be correct");
    println!("\nwebgraph_ranking OK");
}
