//! Dynamic networks (§IV future-work 2): the web changes while PageRank
//! is being tracked. Compares the MP warm restart (local O(N_p) residual
//! repair via the eq. 11 conservation law) against recomputing from
//! scratch after every change.
//!
//! Run with: `cargo run --release --example dynamic_network`

use pagerank_mp::algo::dynamic::{DynamicMatchingPursuit, EdgeEvent};
use pagerank_mp::graph::generators;
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::linalg::vector;
use pagerank_mp::util::rng::Rng;

/// Steps for the tracker to reach the target accuracy.
fn steps_to_tolerance(
    dmp: &mut DynamicMatchingPursuit,
    tol: f64,
    rng: &mut Rng,
    max_steps: usize,
) -> usize {
    let x_star = exact_pagerank(dmp.graph(), 0.85);
    for s in 0..max_steps {
        if vector::dist_sq(dmp.estimate(), &x_star) / x_star.len() as f64 <= tol {
            return s;
        }
        dmp.step(rng);
    }
    max_steps
}

fn main() {
    let n = 100;
    let alpha = 0.85;
    let tol = 1e-10;
    let graph = generators::er_threshold(n, 0.5, 2024);
    let mut rng = Rng::seeded(11);
    let mut churn_rng = Rng::seeded(12);

    // Converge the warm tracker once.
    let mut warm = DynamicMatchingPursuit::new(graph, alpha);
    let initial = steps_to_tolerance(&mut warm, tol, &mut rng, 2_000_000);
    println!("initial convergence: {initial} activations to (1/N)err² ≤ {tol:.0e}\n");
    println!("event              repair-touched  warm steps  cold steps  speedup");

    let mut total_warm = 0usize;
    let mut total_cold = 0usize;
    for event_no in 0..10 {
        // Random churn: alternately add and remove an edge.
        let ev = loop {
            let src = churn_rng.below(n);
            let dst = churn_rng.below(n);
            if src == dst {
                continue;
            }
            let has = warm.graph().has_edge(src, dst);
            if event_no % 2 == 0 && !has {
                break EdgeEvent::Add { src, dst };
            }
            if event_no % 2 == 1 && has && warm.graph().out_degree(src) > 1 {
                break EdgeEvent::Remove { src, dst };
            }
        };

        // Warm restart: local repair, then resume.
        let touched = warm.apply_event(ev).expect("valid event");
        let warm_steps = steps_to_tolerance(&mut warm, tol, &mut rng, 2_000_000);

        // Cold restart baseline on the same new topology.
        let mut cold = DynamicMatchingPursuit::new(warm.graph().clone(), alpha);
        let mut cold_rng = rng.fork(event_no as u64);
        let cold_steps = steps_to_tolerance(&mut cold, tol, &mut cold_rng, 2_000_000);

        total_warm += warm_steps;
        total_cold += cold_steps;
        println!(
            "{:<18} {:>14} {:>11} {:>11} {:>8.1}x",
            format!("{ev:?}").chars().take(18).collect::<String>(),
            touched,
            warm_steps,
            cold_steps,
            cold_steps as f64 / warm_steps.max(1) as f64
        );
    }
    println!(
        "\ntotals: warm {total_warm} vs cold {total_cold} activations \
         ({:.1}x saved by the conservation-law repair)",
        total_cold as f64 / total_warm.max(1) as f64
    );
    assert!(total_warm < total_cold, "warm restart must win overall");
    println!("dynamic_network OK");
}
