//! Quickstart: the paper's §III experiment through the declarative
//! engine API.
//!
//! One [`Scenario`] value names the graph, the solvers and the
//! experiment shape; `run()` produces averaged error trajectories,
//! fitted decay rates and communication totals for every solver
//! uniformly — Algorithm 1 and two of the paper's baselines here.
//!
//! Run with: `cargo run --release --example quickstart`

use pagerank_mp::engine::{GraphSpec, Scenario, SolverSpec};

fn main() {
    // The paper's experiment graph: N=100, iid U[0,1] entries thresholded
    // at 0.5, α = 0.85 (the Scenario default).
    let scenario = Scenario::new("quickstart", GraphSpec::ErThreshold { n: 100, threshold: 0.5 })
        .with_solvers(vec![
            SolverSpec::Mp,
            SolverSpec::YouTempoQiu,
            SolverSpec::IshiiTempo,
        ])
        .with_steps(30_000)
        .with_stride(500)
        .with_rounds(10)
        .with_seed(42);

    // Scenarios are data: the same experiment ships as config and runs
    // via `pagerank-mp run-scenario <file.json>` (see
    // examples/fig1_scenario.json).
    println!("scenario JSON:\n{}\n", scenario.to_json().render());

    let report = scenario.run().expect("quickstart scenario runs");
    println!("{}", report.render());

    let mp = report.get("mp").expect("mp ran");
    let it = report.get("ishii-tempo").expect("baseline ran");
    println!(
        "\nMP per-step rate {:.6} (exponential) vs Ishii–Tempo {:.6} (sub-exponential)",
        mp.decay_rate, it.decay_rate
    );
    println!(
        "MP communication: {} reads / {} writes over {} activations",
        mp.total_stats.reads, mp.total_stats.writes, mp.total_stats.activated
    );
    assert!(mp.decay_rate < 1.0, "MP must decay exponentially");
    assert!(
        mp.final_error < it.final_error,
        "MP must beat the averaging baseline at the horizon"
    );
    println!("quickstart OK");
}
