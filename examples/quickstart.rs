//! Quickstart: the paper's Algorithm 1 on its own §III workload.
//!
//! Builds the N=100 ER-threshold graph, runs the Matching-Pursuit
//! iteration, and verifies against the exact solve of Proposition 1.
//!
//! Run with: `cargo run --release --example quickstart`

use pagerank_mp::algo::common::PageRankSolver;
use pagerank_mp::algo::mp::MatchingPursuit;
use pagerank_mp::graph::generators;
use pagerank_mp::linalg::solve::exact_pagerank;
use pagerank_mp::linalg::vector;
use pagerank_mp::util::rng::Rng;

fn main() {
    // The paper's experiment graph: N=100, iid U[0,1] entries thresholded
    // at 0.5, α = 0.85.
    let n = 100;
    let alpha = 0.85;
    let graph = generators::er_threshold(n, 0.5, 42);
    println!(
        "graph: {} pages, {} links, mean out-degree {:.1}",
        graph.n(),
        graph.m(),
        graph.m() as f64 / graph.n() as f64
    );

    // Ground truth per Proposition 1: x* = (1-α)(I-αA)⁻¹ 𝟙.
    let x_star = exact_pagerank(&graph, alpha);

    // Algorithm 1: each step activates a uniform page, reads the residuals
    // of its out-neighbours, updates its score and their residuals.
    let mut mp = MatchingPursuit::new(&graph, alpha);
    let mut rng = Rng::seeded(7);
    for t in 0..=120_000u64 {
        if t % 20_000 == 0 {
            let err = vector::dist_sq(&mp.estimate(), &x_star) / n as f64;
            println!(
                "t = {t:>7}   (1/N)‖x_t - x*‖² = {err:.3e}   ‖r_t‖² = {:.3e}",
                mp.residual_norm_sq()
            );
        }
        mp.step(&mut rng);
    }

    // Report the final ranking quality.
    let est = mp.estimate();
    let agreement = pagerank_mp::util::stats::ranking_agreement(&est, &x_star);
    println!("\nranking agreement with exact PageRank: {agreement:.4}");
    let ranking = pagerank_mp::util::stats::ranking(&est);
    println!("top 5 pages: {:?}", &ranking[..5]);
    assert!(agreement > 0.999, "quickstart should fully converge");
    println!("quickstart OK");
}
