//! Race every execution backend on one scenario axis: the sequential
//! matrix form (uniform and residual-weighted sampling), the
//! multi-threaded sharded runtime at two shard counts (both shard maps,
//! the serial-leader vs worker-side packers at 8 shards — the
//! centralization the distributed-randomized line of work argues away —
//! and the residual sampling policy), and the dense backend — the
//! comparison the related work (Ishii–Tempo; Das Sarma et al.) frames
//! as "convergence per unit of parallel work". The wall-ms column is
//! where the worker packer's win shows; the error column is where
//! residual weighting's activations-to-ε win shows.
//!
//! Run with: `cargo run --release --example backend_race`

use pagerank_mp::engine::{GraphSpec, Scenario, SolverSpec};

fn main() {
    let scenario = Scenario::new(
        "backend-race",
        GraphSpec::ErThreshold { n: 60, threshold: 0.5 },
    )
    .with_solvers(vec![
        SolverSpec::Mp,
        SolverSpec::parse("mp:residual").expect("registry"),
        SolverSpec::parse("sharded:2:8").expect("registry"),
        SolverSpec::parse("sharded:4:8").expect("registry"),
        SolverSpec::parse("sharded:4:8:block").expect("registry"),
        SolverSpec::parse("sharded:8:64:mod:leader").expect("registry"),
        SolverSpec::parse("sharded:8:64:mod:worker").expect("registry"),
        SolverSpec::parse("sharded:8:64:mod:worker:residual").expect("registry"),
        SolverSpec::Dense,
    ])
    .with_steps(4_000)
    .with_stride(400)
    .with_rounds(5)
    .with_seed(7);

    eprintln!(
        "racing [{}] on {} …",
        scenario.experiment.run_keys().join(", "),
        scenario.graph.key()
    );
    let report = scenario.run().expect("scenario runs");
    println!("{}", report.render());

    println!("decay-rate ordering (fastest first):");
    for (i, (key, rate)) in report.rate_ordering().into_iter().enumerate() {
        println!("  #{} {:<24} rate/step {rate:.6}", i + 1, key);
    }

    println!("\nparallel-work accounting:");
    for r in report.solver_reports() {
        println!(
            "  {:<24} activated {:<8} conflicts dropped {:<6} wall {:>6.0} ms",
            r.spec.key(),
            r.total_stats.activated,
            r.conflicts,
            r.wall.as_secs_f64() * 1e3
        );
    }
}
