//! Algorithm 2 (Appendix): fully distributed network-size estimation.
//!
//! Each page holds one scalar `s_i`; random activations project onto rows
//! of C = (I-A)ᵀ using only out-neighbour communication; `s → 𝟙/N` and
//! every page reads off `N ≈ 1/s_i`. Demonstrates the exponential mean
//! decay of Fig. 2 and the strong-connectivity requirement.
//!
//! Run with: `cargo run --release --example size_estimation`

use pagerank_mp::algo::size_estimation::{SizeEstimationError, SizeEstimator};
use pagerank_mp::engine::{EstimatorSpec, GraphSpec, Scenario};
use pagerank_mp::graph::{generators, GraphBuilder};
use pagerank_mp::util::rng::Rng;

fn main() {
    // --- happy path: the paper's dense ER graph --------------------------
    let n = 100;
    let graph = generators::er_threshold(n, 0.5, 77);
    let mut est = SizeEstimator::new(&graph).expect("dense ER graphs are strongly connected");
    let mut rng = Rng::seeded(3);

    println!("N = {n} (ground truth); s_0 = e_1");
    println!("{:>9}  {:>12}  {:>18}", "t", "‖s-1/N‖²", "page-0 estimate of N");
    for t in 1..=30_000usize {
        est.step(&mut rng);
        if t % 3_000 == 0 {
            let nd = est
                .estimate_at(0)
                .map(|v| format!("{v:.3}"))
                .unwrap_or_else(|| "-".into());
            println!("{t:>9}  {:>12.3e}  {nd:>18}", est.error_sq());
        }
    }
    // every page can now answer "how big is the network?"
    let worst = (0..n)
        .map(|i| est.estimate_at(i).expect("converged"))
        .map(|nd| (nd - n as f64).abs())
        .fold(0.0f64, f64::max);
    println!("\nworst per-page error in N̂: {worst:.2e}");
    assert!(worst < 1e-3);

    // --- the assumption matters: a disconnected graph is rejected --------
    let mut b = GraphBuilder::new(6).dangling_policy(pagerank_mp::graph::DanglingPolicy::SelfLoop);
    b.add_edge(0, 1).add_edge(1, 0).add_edge(2, 3).add_edge(3, 2);
    let disconnected = b.build().expect("builds");
    match SizeEstimator::new(&disconnected) {
        Err(SizeEstimationError::NotStronglyConnected) => {
            println!("disconnected graph correctly rejected (Appendix assumption)");
        }
        other => panic!("expected NotStronglyConnected, got {other:?}"),
    }

    // --- slow topology: the ring still converges, just slower ------------
    let ring = generators::ring(50);
    let mut est = SizeEstimator::new(&ring).expect("ring is strongly connected");
    let mut rng = Rng::seeded(4);
    let e0 = est.error_sq();
    for _ in 0..60_000 {
        est.step(&mut rng);
    }
    println!("ring-50: error {:.2e} -> {:.2e}", e0, est.error_sq());
    assert!(est.error_sq() < 1e-6 * e0);

    // --- the same experiment, declaratively: race the site policies -----
    // (this is the `run-scenario examples/fig2_scenario.json` shape)
    let report = Scenario::new("size-race", GraphSpec::paper(40))
        .with_estimators(EstimatorSpec::all())
        .with_steps(40_000)
        .with_stride(2_000)
        .with_rounds(10)
        .with_seed(2017)
        .run()
        .expect("estimator race runs");
    println!("\ndecay-rate ordering (fastest first):");
    for (i, (key, rate)) in report.rate_ordering().into_iter().enumerate() {
        println!("  #{} {:<10} rate/step {rate:.6}", i + 1, key);
    }
    for r in report.estimator_reports() {
        assert!(r.final_size_rel_err < 1e-2, "{} failed to recover N", r.spec.key());
    }
    println!("size_estimation OK");
}
